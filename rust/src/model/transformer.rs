//! The decoder-only transformer (Rust twin of
//! `python/compile/model.py`): full-sequence forward for evaluation +
//! calibration capture, and incremental decode for serving.

use std::collections::HashMap;

use anyhow::Result;

use super::kvcache::{GatherScratch, KvCache, KvChunk, KvPool, PagedKvCache, PoolConfig};
use super::linear::Linear;
use super::rope::Rope;
use crate::engine::QuantizedActs;
use crate::io::weights::{ModelConfig, RawModel};
use crate::quant::transform::Transform;
use crate::tensor::Matrix;

/// Where calibration activations are captured (inputs of the 7 linears).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CaptureSite {
    /// ln1 output — shared input of wq/wk/wv.
    Ln1Out,
    /// attention mix — input of wo.
    AttnOut,
    /// ln2 output — shared input of wgate/wup.
    Ln2Out,
    /// silu(g)*u — input of wdown.
    FfnMid,
}

/// Captured activation rows per (layer, site), capped at `max_rows`.
#[derive(Debug, Default)]
pub struct Capture {
    pub max_rows: usize,
    pub sites: HashMap<(usize, CaptureSite), Vec<Vec<f32>>>,
}

impl Capture {
    pub fn new(max_rows: usize) -> Capture {
        Capture { max_rows, sites: HashMap::new() }
    }

    fn push(&mut self, layer: usize, site: CaptureSite, x: &Matrix) {
        let rows = self.sites.entry((layer, site)).or_default();
        for r in 0..x.rows {
            if rows.len() >= self.max_rows {
                return;
            }
            rows.push(x.row(r).to_vec());
        }
    }

    /// Materialize one site as a Matrix.
    pub fn matrix(&self, layer: usize, site: CaptureSite) -> Option<Matrix> {
        let rows = self.sites.get(&(layer, site))?;
        if rows.is_empty() {
            return None;
        }
        let cols = rows[0].len();
        let mut m = Matrix::zeros(rows.len(), cols);
        for (i, r) in rows.iter().enumerate() {
            m.row_mut(i).copy_from_slice(r);
        }
        Some(m)
    }
}

/// One transformer block: 7 pluggable linears + 2 norms.
#[derive(Debug, Clone)]
pub struct Block {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub wgate: Linear,
    pub wup: Linear,
    pub wdown: Linear,
    /// Quantize-once flags, refreshed whenever engines are
    /// (re)prepared: `Some(bits)` means the site group's shared input
    /// is quantized to per-row int8 a single time per forward and all
    /// member engines consume the same codes.
    qkv_share: Option<u32>,
    ffn_share: Option<u32>,
}

/// By-value transform equality: two linears can share one transformed
/// input iff their transforms compute the same function.
fn transform_eq(a: &Option<Transform>, b: &Option<Transform>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(x), Some(y)) => x.sigma == y.sigma && x.p1 == y.p1 && x.p2 == y.p2,
        _ => false,
    }
}

/// `Some(bits)` when every linear in a site group runs the integer
/// path at the same width behind the same (by-value) transform — the
/// precondition for quantizing their shared input once.
fn share_bits(lins: &[&Linear]) -> Option<u32> {
    let bits = lins[0].int_bits()?;
    for l in &lins[1..] {
        if l.int_bits() != Some(bits) || !transform_eq(&lins[0].transform, &l.transform) {
            return None;
        }
    }
    Some(bits)
}

impl Block {
    /// Recompute the quantize-once share flags. Called whenever the
    /// engine set changes; any member off the int path clears its
    /// group's flag, so the flags can never go stale-positive.
    fn refresh_share_flags(&mut self) {
        self.qkv_share = share_bits(&[&self.wq, &self.wk, &self.wv]);
        self.ffn_share = share_bits(&[&self.wgate, &self.wup]);
    }

    /// Attention projections from the shared ln1 output. With the
    /// quantize-once flag set, the common input is transformed and
    /// quantized to per-row int8 a single time and all three engines
    /// consume the same codes — bit-identical to three independent
    /// `forward` calls (same transform values, same quantizer) but
    /// paying transform + quantization once instead of three times.
    pub fn qkv_forward(&self, h: &Matrix) -> (Matrix, Matrix, Matrix) {
        if let Some(bits) = self.qkv_share {
            let ht = match &self.wq.transform {
                Some(t) => t.apply(h),
                None => h.clone(),
            };
            let qa = QuantizedActs::quantize(&ht, bits);
            return (
                self.wq.forward_quantized(&qa),
                self.wk.forward_quantized(&qa),
                self.wv.forward_quantized(&qa),
            );
        }
        (self.wq.forward(h), self.wk.forward(h), self.wv.forward(h))
    }

    /// Gate/up projections from the shared ln2 output (same
    /// quantize-once contract as [`Self::qkv_forward`]).
    pub fn ffn_forward(&self, h2: &Matrix) -> (Matrix, Matrix) {
        if let Some(bits) = self.ffn_share {
            let ht = match &self.wgate.transform {
                Some(t) => t.apply(h2),
                None => h2.clone(),
            };
            let qa = QuantizedActs::quantize(&ht, bits);
            return (self.wgate.forward_quantized(&qa), self.wup.forward_quantized(&qa));
        }
        (self.wgate.forward(h2), self.wup.forward(h2))
    }
    /// Iterate the 7 linears with their names (pipeline, accounting).
    pub fn linears_mut(&mut self) -> [(&'static str, &mut Linear); 7] {
        [
            ("wq", &mut self.wq),
            ("wk", &mut self.wk),
            ("wv", &mut self.wv),
            ("wo", &mut self.wo),
            ("wgate", &mut self.wgate),
            ("wup", &mut self.wup),
            ("wdown", &mut self.wdown),
        ]
    }

    pub fn linears(&self) -> [(&'static str, &Linear); 7] {
        [
            ("wq", &self.wq),
            ("wk", &self.wk),
            ("wv", &self.wv),
            ("wo", &self.wo),
            ("wgate", &self.wgate),
            ("wup", &self.wup),
            ("wdown", &self.wdown),
        ]
    }
}

/// The model.
#[derive(Debug, Clone)]
pub struct Transformer {
    pub cfg: ModelConfig,
    pub emb: Matrix,
    pub lnf: Vec<f32>,
    pub blocks: Vec<Block>,
    pub rope: Rope,
}

fn rmsnorm_rows(x: &Matrix, w: &[f32]) -> Matrix {
    let mut out = x.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for (v, &wi) in row.iter_mut().zip(w.iter()) {
            *v = *v * inv * wi;
        }
    }
    out
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn softmax_inplace(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0f32;
    for v in xs.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in xs.iter_mut() {
        *v /= sum;
    }
}

/// One query row attending over a gathered context (GQA: `rep` query
/// heads share each KV head). The context arrives as position-ordered
/// [`KvChunk`]s — one for a flat cache, one per block for a paged
/// cache — and the per-head score/softmax/axpy order is identical
/// however the rows are chunked, so the flat and paged paths cannot
/// drift apart (the bit-identity contract). Shared by
/// [`Transformer::decode_batch`] and [`Transformer::prefill`] in both
/// cache shapes.
#[allow(clippy::too_many_arguments)]
fn attend_chunks(
    qrow: &[f32],
    chunks: &[KvChunk<'_>],
    kv_dim: usize,
    nh: usize,
    rep: usize,
    hd: usize,
    scale: f32,
    orow: &mut [f32],
) {
    let ctx: usize = chunks.iter().map(|c| c.n).sum();
    let mut scores = vec![0f32; ctx];
    for hh in 0..nh {
        let kvh = hh / rep;
        let qv = &qrow[hh * hd..(hh + 1) * hd];
        let mut base = 0;
        for ch in chunks {
            for i in 0..ch.n {
                let kv = &ch.k[i * kv_dim + kvh * hd..i * kv_dim + (kvh + 1) * hd];
                scores[base + i] = crate::tensor::matrix::dot(qv, kv) * scale;
            }
            base += ch.n;
        }
        softmax_inplace(&mut scores);
        let out = &mut orow[hh * hd..(hh + 1) * hd];
        base = 0;
        for ch in chunks {
            for i in 0..ch.n {
                let vv = &ch.v[i * kv_dim + kvh * hd..i * kv_dim + (kvh + 1) * hd];
                crate::tensor::matrix::axpy(scores[base + i], vv, out);
            }
            base += ch.n;
        }
    }
}

/// Truncate a position-ordered chunk list to its first `ctx` rows
/// (the causal prefix a prefill query row may see). Pure slicing — the
/// gathered bytes are untouched, so attention over the clipped list is
/// bit-identical to a fresh gather of `ctx` rows.
fn clip_chunks<'a>(chunks: &[KvChunk<'a>], ctx: usize, kv_dim: usize) -> Vec<KvChunk<'a>> {
    let mut out = Vec::with_capacity(chunks.len());
    let mut remaining = ctx;
    for ch in chunks {
        if remaining == 0 {
            break;
        }
        let n = ch.n.min(remaining);
        out.push(KvChunk { k: &ch.k[..n * kv_dim], v: &ch.v[..n * kv_dim], n });
        remaining -= n;
    }
    debug_assert_eq!(remaining, 0, "clip past the gathered context");
    out
}

/// Where a forward's K/V lives: flat per-request caches or paged
/// caches backed by a shared [`KvPool`]. The decode/prefill bodies are
/// written once against this, so the two storage shapes can never
/// diverge arithmetically.
enum KvTarget<'a> {
    Flat(&'a mut [KvCache]),
    Paged { caches: &'a mut [PagedKvCache], pool: &'a mut KvPool },
}

impl KvTarget<'_> {
    fn count(&self) -> usize {
        match self {
            KvTarget::Flat(c) => c.len(),
            KvTarget::Paged { caches, .. } => caches.len(),
        }
    }

    fn len(&self, b: usize) -> usize {
        match self {
            KvTarget::Flat(c) => c[b].len(),
            KvTarget::Paged { caches, .. } => caches[b].len(),
        }
    }

    /// Make room for `extra` appended positions. The paged pool is
    /// bounded: the serving scheduler checks capacity *before* running
    /// a forward (deferring or preempting when full), so exhaustion
    /// here is an API-misuse panic, not a serving-path event.
    fn reserve(&mut self, b: usize, extra: usize) {
        if let KvTarget::Paged { caches, pool } = self {
            assert!(
                pool.ensure_append(&mut caches[b], extra),
                "KV pool exhausted mid-forward: callers must check capacity first \
                 (scheduler defers/preempts; see DESIGN.md §8)"
            );
        }
    }

    fn push(&mut self, b: usize, li: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        match self {
            KvTarget::Flat(c) => c[b].layers[li].push(k_row, v_row),
            KvTarget::Paged { caches, pool } => pool.append_row(&caches[b], li, pos, k_row, v_row),
        }
    }

    /// Commit `n` appended positions on request `b` (flat caches track
    /// length per layer push; paged caches commit once per forward).
    fn advance(&mut self, b: usize, n: usize) {
        if let KvTarget::Paged { caches, .. } = self {
            caches[b].advance(n);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn attend(
        &self,
        scratch: &mut GatherScratch,
        b: usize,
        li: usize,
        ctx: usize,
        qrow: &[f32],
        nh: usize,
        rep: usize,
        hd: usize,
        scale: f32,
        orow: &mut [f32],
    ) {
        match self {
            KvTarget::Flat(c) => {
                let l = &c[b].layers[li];
                let one = [KvChunk { k: &l.k[..ctx * l.kv_dim], v: &l.v[..ctx * l.kv_dim], n: ctx }];
                attend_chunks(qrow, &one, l.kv_dim, nh, rep, hd, scale, orow);
            }
            KvTarget::Paged { caches, pool } => {
                let chunks = pool.gather(&caches[b], li, ctx, scratch);
                attend_chunks(qrow, &chunks, pool.kv_dim(), nh, rep, hd, scale, orow);
            }
        }
    }

    /// Attend every prefill row of `q` against its causal prefix
    /// (`ctx = base + i + 1`). One gather per layer — cold blocks
    /// dequantize once, and each row sees a clipped view of the same
    /// chunk list (bit-identical to per-row gathers).
    #[allow(clippy::too_many_arguments)]
    fn attend_rows(
        &self,
        scratch: &mut GatherScratch,
        b: usize,
        li: usize,
        base: usize,
        q: &Matrix,
        nh: usize,
        rep: usize,
        hd: usize,
        scale: f32,
        attn_out: &mut Matrix,
    ) {
        let s = q.rows;
        match self {
            KvTarget::Flat(c) => {
                let l = &c[b].layers[li];
                for i in 0..s {
                    let ctx = base + i + 1;
                    let one =
                        [KvChunk { k: &l.k[..ctx * l.kv_dim], v: &l.v[..ctx * l.kv_dim], n: ctx }];
                    attend_chunks(q.row(i), &one, l.kv_dim, nh, rep, hd, scale, attn_out.row_mut(i));
                }
            }
            KvTarget::Paged { caches, pool } => {
                let kvd = pool.kv_dim();
                let chunks = pool.gather(&caches[b], li, base + s, scratch);
                for i in 0..s {
                    let clipped = clip_chunks(&chunks, base + i + 1, kvd);
                    attend_chunks(q.row(i), &clipped, kvd, nh, rep, hd, scale, attn_out.row_mut(i));
                }
            }
        }
    }
}

impl Transformer {
    /// Build from a TLM1 blob with dense fp32 backends.
    pub fn from_raw(raw: &RawModel) -> Result<Transformer> {
        let cfg = raw.config.clone();
        let mut blocks = Vec::with_capacity(cfg.n_layer);
        for i in 0..cfg.n_layer {
            blocks.push(Block {
                ln1: raw.vector(&format!("l{i}.ln1"))?,
                ln2: raw.vector(&format!("l{i}.ln2"))?,
                wq: Linear::dense(raw.matrix(&format!("l{i}.wq"))?),
                wk: Linear::dense(raw.matrix(&format!("l{i}.wk"))?),
                wv: Linear::dense(raw.matrix(&format!("l{i}.wv"))?),
                wo: Linear::dense(raw.matrix(&format!("l{i}.wo"))?),
                wgate: Linear::dense(raw.matrix(&format!("l{i}.wgate"))?),
                wup: Linear::dense(raw.matrix(&format!("l{i}.wup"))?),
                wdown: Linear::dense(raw.matrix(&format!("l{i}.wdown"))?),
                qkv_share: None,
                ffn_share: None,
            });
        }
        let rope = Rope::new(cfg.head_dim(), cfg.max_seq.max(512), cfg.rope_theta);
        Ok(Transformer {
            emb: raw.matrix("emb")?,
            lnf: raw.vector("lnf")?,
            rope,
            cfg,
            blocks,
        })
    }

    /// Full-sequence forward: tokens -> logits (seq, vocab).
    pub fn forward(&self, tokens: &[u16]) -> Matrix {
        self.forward_capture(tokens, &mut None)
    }

    /// Forward with optional calibration capture.
    pub fn forward_capture(&self, tokens: &[u16], capture: &mut Option<&mut Capture>) -> Matrix {
        let s = tokens.len();
        let d = self.cfg.d_model;
        let (nh, nkv, hd) = (self.cfg.n_head, self.cfg.n_kv_head, self.cfg.head_dim());
        let rep = nh / nkv;
        let mut x = Matrix::zeros(s, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.emb.row(t as usize));
        }
        for (li, block) in self.blocks.iter().enumerate() {
            // ---- attention ----
            let h = rmsnorm_rows(&x, &block.ln1);
            if let Some(c) = capture.as_deref_mut() {
                c.push(li, CaptureSite::Ln1Out, &h);
            }
            let (mut q, mut k, v) = block.qkv_forward(&h); // (s, d), 2x (s, kv_dim)
            for pos in 0..s {
                let qrow = q.row_mut(pos);
                for hh in 0..nh {
                    self.rope.apply(&mut qrow[hh * hd..(hh + 1) * hd], pos);
                }
                let krow = k.row_mut(pos);
                for hh in 0..nkv {
                    self.rope.apply(&mut krow[hh * hd..(hh + 1) * hd], pos);
                }
            }
            let scale = 1.0 / (hd as f32).sqrt();
            let mut attn_out = Matrix::zeros(s, d);
            let mut scores = vec![0f32; s];
            for hh in 0..nh {
                let kvh = hh / rep;
                for qi in 0..s {
                    let qv = &q.row(qi)[hh * hd..(hh + 1) * hd];
                    for ki in 0..=qi {
                        let kv = &k.row(ki)[kvh * hd..(kvh + 1) * hd];
                        scores[ki] = crate::tensor::matrix::dot(qv, kv) * scale;
                    }
                    softmax_inplace(&mut scores[..=qi]);
                    let orow = attn_out.row_mut(qi);
                    for ki in 0..=qi {
                        let vv = &v.row(ki)[kvh * hd..(kvh + 1) * hd];
                        crate::tensor::matrix::axpy(scores[ki], vv, &mut orow[hh * hd..(hh + 1) * hd]);
                    }
                }
            }
            if let Some(c) = capture.as_deref_mut() {
                c.push(li, CaptureSite::AttnOut, &attn_out);
            }
            x = x.add(&block.wo.forward(&attn_out));

            // ---- ffn ----
            let h2 = rmsnorm_rows(&x, &block.ln2);
            if let Some(c) = capture.as_deref_mut() {
                c.push(li, CaptureSite::Ln2Out, &h2);
            }
            let (g, u) = block.ffn_forward(&h2);
            let mut mid = g;
            for (mv, uv) in mid.data.iter_mut().zip(u.data.iter()) {
                *mv = silu(*mv) * uv;
            }
            if let Some(c) = capture.as_deref_mut() {
                c.push(li, CaptureSite::FfnMid, &mid);
            }
            x = x.add(&block.wdown.forward(&mid));
        }
        let xf = rmsnorm_rows(&x, &self.lnf);
        xf.matmul_bt(&self.emb) // tied embedding
    }

    /// Incremental decode: run one token at position `cache.len()`,
    /// appending K/V to the cache. Returns logits (vocab,).
    ///
    /// Single-request view of [`Self::decode_batch`]; bit-identical to
    /// a batch of one by construction.
    pub fn decode_step(&self, token: u16, cache: &mut KvCache) -> Vec<f32> {
        self.decode_batch(&[token], std::slice::from_mut(cache)).row(0).to_vec()
    }

    /// Fused batch decode: one token per request, each at its own
    /// cache position. Stacks the B single-token rows into one (B, d)
    /// activation so every linear/engine forward runs **once** per
    /// layer per round (the batch amortization the serving loop relies
    /// on). Returns logits (B, vocab); row `b` is bit-identical to
    /// `decode_step(tokens[b], &mut caches[b])` run alone, because
    /// every kernel on the path computes output rows independently.
    pub fn decode_batch(&self, tokens: &[u16], caches: &mut [KvCache]) -> Matrix {
        self.decode_batch_impl(tokens, KvTarget::Flat(caches))
    }

    /// [`Self::decode_batch`] over paged caches backed by `pool`.
    /// With quantization off the logits and gathered K/V bytes are
    /// bit-identical to the flat path (pinned by
    /// `rust/tests/batch_equivalence.rs`); with quantization on, cold
    /// context reads the dequantized int rows. Capacity for one
    /// position per cache must be available — the scheduler checks
    /// before every round (deferring or preempting when the pool is
    /// full), so exhaustion here panics as API misuse.
    pub fn decode_batch_paged(
        &self,
        tokens: &[u16],
        caches: &mut [PagedKvCache],
        pool: &mut KvPool,
    ) -> Matrix {
        self.decode_batch_impl(tokens, KvTarget::Paged { caches, pool })
    }

    fn decode_batch_impl(&self, tokens: &[u16], mut kv: KvTarget<'_>) -> Matrix {
        assert_eq!(tokens.len(), kv.count(), "one cache per request");
        let bsz = tokens.len();
        if bsz == 0 {
            return Matrix::zeros(0, self.cfg.vocab);
        }
        let d = self.cfg.d_model;
        let (nh, nkv, hd) = (self.cfg.n_head, self.cfg.n_kv_head, self.cfg.head_dim());
        let rep = nh / nkv;
        let pos: Vec<usize> = (0..bsz).map(|b| kv.len(b)).collect();
        for b in 0..bsz {
            kv.reserve(b, 1);
        }
        let mut scratch = GatherScratch::new();
        let mut x = Matrix::zeros(bsz, d);
        for (b, &t) in tokens.iter().enumerate() {
            x.row_mut(b).copy_from_slice(self.emb.row(t as usize));
        }
        for (li, block) in self.blocks.iter().enumerate() {
            let h = rmsnorm_rows(&x, &block.ln1);
            // Quantize-once: the B stacked rows are quantized a single
            // time and shared across q/k/v (and gate/up below).
            let (mut q, mut k, v) = block.qkv_forward(&h);
            for b in 0..bsz {
                let qrow = q.row_mut(b);
                for hh in 0..nh {
                    self.rope.apply(&mut qrow[hh * hd..(hh + 1) * hd], pos[b]);
                }
                let krow = k.row_mut(b);
                for hh in 0..nkv {
                    self.rope.apply(&mut krow[hh * hd..(hh + 1) * hd], pos[b]);
                }
                kv.push(b, li, pos[b], k.row(b), v.row(b));
            }
            let scale = 1.0 / (hd as f32).sqrt();
            let mut attn_out = Matrix::zeros(bsz, d);
            for b in 0..bsz {
                kv.attend(
                    &mut scratch,
                    b,
                    li,
                    pos[b] + 1,
                    q.row(b),
                    nh,
                    rep,
                    hd,
                    scale,
                    attn_out.row_mut(b),
                );
            }
            x = x.add(&block.wo.forward(&attn_out));
            let h2 = rmsnorm_rows(&x, &block.ln2);
            let (g, u) = block.ffn_forward(&h2);
            let mut mid = g;
            for (mv, uv) in mid.data.iter_mut().zip(u.data.iter()) {
                *mv = silu(*mv) * uv;
            }
            x = x.add(&block.wdown.forward(&mid));
        }
        for b in 0..bsz {
            kv.advance(b, 1);
        }
        let xf = rmsnorm_rows(&x, &self.lnf);
        xf.matmul_bt(&self.emb)
    }

    /// Batched prefill: run the whole prompt through the full-sequence
    /// path (one (s, d) GEMM per linear instead of s GEMVs), appending
    /// K/V for every position to `cache`. Supports chunked prefill:
    /// positions start at `cache.len()`. Returns the logits of the
    /// **last** prompt token (the only row decoding needs) —
    /// bit-identical to feeding the tokens through `decode_step` one
    /// at a time. Empty `tokens` returns an empty vec.
    pub fn prefill(&self, tokens: &[u16], cache: &mut KvCache) -> Vec<f32> {
        self.last_logits(self.prefill_hidden(tokens, KvTarget::Flat(std::slice::from_mut(cache))))
    }

    /// [`Self::prefill`] over a paged cache backed by `pool` (chunked
    /// prefill supported the same way: positions continue from
    /// `cache.len()`). Capacity for `tokens.len()` more positions must
    /// be available — the scheduler checks first; exhaustion panics.
    pub fn prefill_paged(
        &self,
        tokens: &[u16],
        cache: &mut PagedKvCache,
        pool: &mut KvPool,
    ) -> Vec<f32> {
        let caches = std::slice::from_mut(cache);
        self.last_logits(self.prefill_hidden(tokens, KvTarget::Paged { caches, pool }))
    }

    /// Logit only the last position: one (1, vocab) GEMV instead of
    /// the s lm-head GEMVs the incremental prefill paid.
    fn last_logits(&self, hidden: Option<Matrix>) -> Vec<f32> {
        match hidden {
            Some(x) => {
                let mut last = Matrix::zeros(1, x.cols);
                last.row_mut(0).copy_from_slice(x.row(x.rows - 1));
                let xf = rmsnorm_rows(&last, &self.lnf);
                xf.matmul_bt(&self.emb).row(0).to_vec()
            }
            None => Vec::new(),
        }
    }

    /// Speculative verification forward: feed `tokens` (the pending
    /// next token plus a drafted continuation) starting at
    /// `cache.len()`, appending K/V for every position, and return
    /// logits for **all** fed rows as a (s, vocab) matrix. Row `i` is
    /// bit-identical to what `decode_batch_paged` would produce after
    /// consuming `tokens[..=i]` one at a time — the same shared
    /// prefill body behind the pinned chunked-prefill equivalence —
    /// which is what makes greedy speculative acceptance exact. The
    /// caller rolls rejected tail positions back with
    /// [`KvPool::truncate`]. Capacity for `tokens.len()` positions
    /// must be ensured first; exhaustion panics as API misuse.
    pub fn verify_paged(
        &self,
        tokens: &[u16],
        cache: &mut PagedKvCache,
        pool: &mut KvPool,
    ) -> Matrix {
        let caches = std::slice::from_mut(cache);
        match self.prefill_hidden(tokens, KvTarget::Paged { caches, pool }) {
            Some(x) => rmsnorm_rows(&x, &self.lnf).matmul_bt(&self.emb),
            None => Matrix::zeros(0, self.cfg.vocab),
        }
    }

    /// [`Self::prefill`] without the lm-head projection — for
    /// mid-prompt chunks whose logits nobody reads (the continuous-
    /// batching scheduler only samples from the *final* chunk). K/V
    /// side effects are identical to [`Self::prefill`].
    pub fn prefill_extend(&self, tokens: &[u16], cache: &mut KvCache) {
        let _ = self.prefill_hidden(tokens, KvTarget::Flat(std::slice::from_mut(cache)));
    }

    /// Paged twin of [`Self::prefill_extend`].
    pub fn prefill_extend_paged(
        &self,
        tokens: &[u16],
        cache: &mut PagedKvCache,
        pool: &mut KvPool,
    ) {
        let caches = std::slice::from_mut(cache);
        let _ = self.prefill_hidden(tokens, KvTarget::Paged { caches, pool });
    }

    /// Shared prefill body: appends K/V for every position and returns
    /// every position's final hidden state as a (s, d) matrix
    /// (pre-lnf), or `None` for empty `tokens`. Prefill callers read
    /// only the last row; [`Self::verify_paged`] projects all of them.
    fn prefill_hidden(&self, tokens: &[u16], mut kv: KvTarget<'_>) -> Option<Matrix> {
        let s = tokens.len();
        if s == 0 {
            return None;
        }
        let d = self.cfg.d_model;
        let (nh, nkv, hd) = (self.cfg.n_head, self.cfg.n_kv_head, self.cfg.head_dim());
        let rep = nh / nkv;
        let base = kv.len(0);
        kv.reserve(0, s);
        let mut scratch = GatherScratch::new();
        let mut x = Matrix::zeros(s, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.emb.row(t as usize));
        }
        for (li, block) in self.blocks.iter().enumerate() {
            let h = rmsnorm_rows(&x, &block.ln1);
            // Quantize-once: all s prompt rows quantize a single time.
            let (mut q, mut k, v) = block.qkv_forward(&h);
            for i in 0..s {
                let qrow = q.row_mut(i);
                for hh in 0..nh {
                    self.rope.apply(&mut qrow[hh * hd..(hh + 1) * hd], base + i);
                }
                let krow = k.row_mut(i);
                for hh in 0..nkv {
                    self.rope.apply(&mut krow[hh * hd..(hh + 1) * hd], base + i);
                }
                kv.push(0, li, base + i, k.row(i), v.row(i));
            }
            let scale = 1.0 / (hd as f32).sqrt();
            let mut attn_out = Matrix::zeros(s, d);
            // Causal: query at absolute position base+i sees cache
            // positions 0..=base+i (its own K/V already pushed). One
            // gather per layer; rows attend over clipped views.
            kv.attend_rows(&mut scratch, 0, li, base, &q, nh, rep, hd, scale, &mut attn_out);
            x = x.add(&block.wo.forward(&attn_out));
            let h2 = rmsnorm_rows(&x, &block.ln2);
            let (g, u) = block.ffn_forward(&h2);
            let mut mid = g;
            for (mv, uv) in mid.data.iter_mut().zip(u.data.iter()) {
                *mv = silu(*mv) * uv;
            }
            x = x.add(&block.wdown.forward(&mid));
        }
        kv.advance(0, s);
        Some(x)
    }

    /// Prepare serving engines on every linear, then refresh the
    /// per-block quantize-once flags.
    pub fn prepare_engines(&mut self) {
        for b in self.blocks.iter_mut() {
            for (_, lin) in b.linears_mut() {
                lin.prepare_engine();
            }
            b.refresh_share_flags();
        }
    }

    /// Prepare engines only where none is prepared yet (idempotent —
    /// the server calls this at startup without redoing caller work).
    pub fn ensure_engines(&mut self) {
        for b in self.blocks.iter_mut() {
            for (_, lin) in b.linears_mut() {
                lin.ensure_engine();
            }
            b.refresh_share_flags();
        }
    }

    /// Cache dense reconstructions on every linear (fast eval). This
    /// is the f32 sim-quant reference path, so the int-path share
    /// flags clear along with it.
    pub fn cache_dense_all(&mut self) {
        for b in self.blocks.iter_mut() {
            for (_, lin) in b.linears_mut() {
                lin.cache_dense();
            }
            b.refresh_share_flags();
        }
    }

    /// Fresh KV cache sized for `capacity` positions.
    pub fn new_cache(&self, capacity: usize) -> KvCache {
        KvCache::new(self.cfg.n_layer, self.cfg.kv_dim(), capacity)
    }

    /// Max positions one sequence can ever occupy (the RoPE table
    /// bound — the same limit the flat path has always had).
    pub fn max_positions(&self) -> usize {
        self.cfg.max_seq.max(512)
    }

    /// A [`KvPool`] shaped for this model. `cfg.budget_blocks == 0`
    /// auto-sizes for `slots` worst-case sequences
    /// ([`Self::max_positions`] each) — the single resolution point of
    /// the auto sentinel, so every entry path (scheduler, tests,
    /// tools) means the same thing by it. Blocks allocate lazily, so a
    /// generous budget costs nothing until used.
    pub fn new_pool(&self, cfg: &PoolConfig, slots: usize) -> KvPool {
        let budget = if cfg.budget_blocks == 0 {
            slots.max(1) * (self.max_positions() + 1).div_ceil(cfg.block_size)
        } else {
            cfg.budget_blocks
        };
        KvPool::new(self.cfg.n_layer, self.cfg.kv_dim(), cfg.block_size, budget, cfg.quant)
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::util::proptest::assert_close;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;

    /// A tiny random model for hermetic tests.
    pub fn tiny_model(seed: u64, n_kv_head: usize) -> Transformer {
        let mut rng = Rng::new(seed);
        let cfg = ModelConfig {
            vocab: 32,
            d_model: 16,
            n_layer: 2,
            n_head: 4,
            n_kv_head,
            d_ff: 24,
            max_seq: 64,
            rope_theta: 10000.0,
        };
        let mut tensors = BTreeMap::new();
        fn add(
            tensors: &mut BTreeMap<String, (Vec<usize>, Vec<f32>)>,
            name: String,
            rows: usize,
            cols: usize,
            rng: &mut Rng,
        ) {
            let m = Matrix::randn(rows, cols, rng).scale(0.15);
            tensors.insert(name, (vec![rows, cols], m.data));
        }
        add(&mut tensors, "emb".into(), cfg.vocab, cfg.d_model, &mut rng);
        tensors.insert("lnf".into(), (vec![cfg.d_model], vec![1.0; cfg.d_model]));
        for i in 0..cfg.n_layer {
            tensors.insert(format!("l{i}.ln1"), (vec![cfg.d_model], vec![1.0; cfg.d_model]));
            tensors.insert(format!("l{i}.ln2"), (vec![cfg.d_model], vec![1.0; cfg.d_model]));
            add(&mut tensors, format!("l{i}.wq"), cfg.d_model, cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wk"), cfg.kv_dim(), cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wv"), cfg.kv_dim(), cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wo"), cfg.d_model, cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wgate"), cfg.d_ff, cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wup"), cfg.d_ff, cfg.d_model, &mut rng);
            add(&mut tensors, format!("l{i}.wdown"), cfg.d_model, cfg.d_ff, &mut rng);
        }
        Transformer::from_raw(&RawModel { config: cfg, tensors }).unwrap()
    }

    #[test]
    fn forward_shape_and_finite() {
        let m = tiny_model(1, 4);
        let logits = m.forward(&[1, 2, 3, 4, 5]);
        assert_eq!(logits.rows, 5);
        assert_eq!(logits.cols, 32);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        let m = tiny_model(2, 4);
        let l1 = m.forward(&[1, 2, 3, 4]);
        let l2 = m.forward(&[1, 2, 3, 9]);
        // logits at positions 0..2 must be identical.
        for r in 0..3 {
            assert_close(l1.row(r), l2.row(r), 1e-5, 1e-5).unwrap();
        }
        // position 3 must differ (different input).
        assert!(l1.row(3).iter().zip(l2.row(3)).any(|(a, b)| (a - b).abs() > 1e-6));
    }

    #[test]
    fn decode_matches_full_forward() {
        // Incremental decoding must reproduce the full forward exactly.
        for nkv in [4usize, 2] {
            let m = tiny_model(3, nkv);
            let tokens = [5u16, 9, 1, 30, 7];
            let full = m.forward(&tokens);
            let mut cache = m.new_cache(8);
            let mut last = Vec::new();
            for &t in &tokens {
                last = m.decode_step(t, &mut cache);
            }
            assert_close(&last, full.row(tokens.len() - 1), 1e-4, 1e-4)
                .unwrap_or_else(|e| panic!("nkv={nkv}: {e}"));
        }
    }

    /// Bitwise equality of two caches (positions, K and V payloads).
    fn assert_caches_identical(a: &KvCache, b: &KvCache) {
        assert_eq!(a.layers.len(), b.layers.len());
        for (la, lb) in a.layers.iter().zip(b.layers.iter()) {
            assert_eq!(la.len, lb.len);
            assert_eq!(la.k, lb.k, "K payload differs");
            assert_eq!(la.v, lb.v, "V payload differs");
        }
    }

    #[test]
    fn prefill_bit_identical_to_decode_steps() {
        for nkv in [4usize, 2] {
            let m = tiny_model(7, nkv);
            let tokens = [3u16, 17, 2, 29, 11, 5];
            let mut c_fast = m.new_cache(8);
            let fast = m.prefill(&tokens, &mut c_fast);
            let mut c_slow = m.new_cache(8);
            let mut slow = Vec::new();
            for &t in &tokens {
                slow = m.decode_step(t, &mut c_slow);
            }
            assert_eq!(fast, slow, "nkv={nkv}: prefill logits differ");
            assert_caches_identical(&c_fast, &c_slow);
        }
    }

    #[test]
    fn prefill_empty_prompt_is_noop() {
        let m = tiny_model(8, 4);
        let mut c = m.new_cache(4);
        assert!(m.prefill(&[], &mut c).is_empty());
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn chunked_prefill_matches_whole_prompt() {
        let m = tiny_model(9, 2);
        let tokens = [4u16, 9, 23, 1, 16];
        let mut c_whole = m.new_cache(8);
        let whole = m.prefill(&tokens, &mut c_whole);
        let mut c_chunk = m.new_cache(8);
        m.prefill(&tokens[..2], &mut c_chunk);
        let chunked = m.prefill(&tokens[2..], &mut c_chunk);
        assert_eq!(whole, chunked);
        assert_caches_identical(&c_whole, &c_chunk);
        // prefill_extend (no lm head) must leave the identical cache,
        // so extend-then-final-prefill matches the whole prompt too.
        let mut c_ext = m.new_cache(8);
        m.prefill_extend(&tokens[..3], &mut c_ext);
        let extended = m.prefill(&tokens[3..], &mut c_ext);
        assert_eq!(whole, extended);
        assert_caches_identical(&c_whole, &c_ext);
    }

    #[test]
    fn decode_batch_bit_identical_to_single_steps() {
        let m = tiny_model(10, 4);
        // Mixed-length histories: request b prefilled with b+1 tokens.
        let histories: [&[u16]; 3] = [&[5], &[7, 2], &[9, 1, 30]];
        let mut batch_caches: Vec<_> = (0..3).map(|_| m.new_cache(8)).collect();
        let mut solo_caches: Vec<_> = (0..3).map(|_| m.new_cache(8)).collect();
        for (b, h) in histories.iter().enumerate() {
            m.prefill(h, &mut batch_caches[b]);
            m.prefill(h, &mut solo_caches[b]);
        }
        let next = [12u16, 3, 25];
        let batched = m.decode_batch(&next, &mut batch_caches);
        for b in 0..3 {
            let solo = m.decode_step(next[b], &mut solo_caches[b]);
            assert_eq!(batched.row(b), &solo[..], "row {b} differs");
            assert_caches_identical(&batch_caches[b], &solo_caches[b]);
        }
    }

    #[test]
    fn decode_batch_empty_is_empty() {
        let m = tiny_model(11, 4);
        let out = m.decode_batch(&[], &mut []);
        assert_eq!(out.rows, 0);
        assert_eq!(out.cols, m.cfg.vocab);
    }

    /// Paged bitwise oracle: gathered pool rows == flat cache rows.
    fn assert_paged_matches_flat(pool: &KvPool, paged: &PagedKvCache, flat: &KvCache) {
        assert_eq!(paged.len(), flat.len());
        for (li, l) in flat.layers.iter().enumerate() {
            let (k, v) = pool.materialize(paged, li);
            assert_eq!(k, l.k, "layer {li} K payload differs");
            assert_eq!(v, l.v, "layer {li} V payload differs");
        }
    }

    #[test]
    fn paged_prefill_and_decode_bit_identical_to_flat() {
        // Block size 3 deliberately does not divide anything: every
        // gather crosses block boundaries.
        for nkv in [4usize, 2] {
            let m = tiny_model(13, nkv);
            let cfg = PoolConfig { block_size: 3, budget_blocks: 0, ..PoolConfig::default() };
            let mut pool = m.new_pool(&cfg, 1);
            let prompt = [3u16, 17, 2, 29, 11, 5, 7];
            let mut flat = m.new_cache(16);
            let flat_logits = m.prefill(&prompt, &mut flat);
            let mut paged = pool.new_cache();
            let paged_logits = m.prefill_paged(&prompt, &mut paged, &mut pool);
            assert_eq!(flat_logits, paged_logits, "nkv={nkv}: prefill logits differ");
            assert_paged_matches_flat(&pool, &paged, &flat);
            // Chunked paged prefill (extend + final) matches too.
            let mut paged2 = pool.new_cache();
            m.prefill_extend_paged(&prompt[..4], &mut paged2, &mut pool);
            let chunked = m.prefill_paged(&prompt[4..], &mut paged2, &mut pool);
            assert_eq!(flat_logits, chunked);
            // Decode rounds: fused paged batch vs fused flat batch.
            let mut flat2 = m.new_cache(16);
            m.prefill(&[9, 1], &mut flat2);
            let mut paged3 = pool.new_cache();
            m.prefill_paged(&[9, 1], &mut paged3, &mut pool);
            let mut flats = [flat, flat2];
            let mut pageds = [paged, paged3];
            for round in 0..4 {
                let next = [(round * 5 + 2) as u16, (round * 3 + 8) as u16];
                let a = m.decode_batch(&next, &mut flats);
                let b = m.decode_batch_paged(&next, &mut pageds, &mut pool);
                assert_eq!(a.data, b.data, "nkv={nkv} round {round}: decode logits differ");
                for i in 0..2 {
                    assert_paged_matches_flat(&pool, &pageds[i], &flats[i]);
                }
            }
            for mut c in pageds {
                pool.release(&mut c);
            }
            pool.release(&mut paged2);
            assert_eq!(pool.blocks_in_use(), 0);
        }
    }

    #[test]
    fn verify_paged_rows_bit_identical_to_sequential_decode() {
        // The speculative-verification contract: one multi-position
        // verify forward produces, for every fed row, exactly the
        // logits sequential decode steps would have produced — and
        // identical K/V bytes — so greedy acceptance is exact.
        for nkv in [4usize, 2] {
            let m = tiny_model(23, nkv);
            let cfg = PoolConfig { block_size: 3, budget_blocks: 0, ..PoolConfig::default() };
            let mut pool = m.new_pool(&cfg, 2);
            let prompt = [3u16, 17, 2, 29, 11];
            let fed = [7u16, 21, 4, 9];
            let mut seq = pool.new_cache();
            m.prefill_paged(&prompt, &mut seq, &mut pool);
            let mut spec = pool.new_cache();
            m.prefill_paged(&prompt, &mut spec, &mut pool);
            let verify = m.verify_paged(&fed, &mut spec, &mut pool);
            assert_eq!(verify.rows, fed.len());
            for (i, &t) in fed.iter().enumerate() {
                let solo = m.decode_batch_paged(&[t], std::slice::from_mut(&mut seq), &mut pool);
                assert_eq!(verify.row(i), solo.row(0), "nkv={nkv}: verify row {i} differs");
            }
            assert_eq!(spec.len(), seq.len());
            for li in 0..m.cfg.n_layer {
                assert_eq!(
                    pool.materialize(&spec, li),
                    pool.materialize(&seq, li),
                    "nkv={nkv}: layer {li} K/V differ after verify"
                );
            }
            // Rollback: truncate the rejected tail, then decoding from
            // the truncated state matches a never-speculated cache.
            let keep = prompt.len() + 2;
            pool.truncate(&mut spec, keep);
            pool.truncate(&mut seq, keep);
            let a = m.decode_batch_paged(&[19], std::slice::from_mut(&mut spec), &mut pool);
            let b = m.decode_batch_paged(&[19], std::slice::from_mut(&mut seq), &mut pool);
            assert_eq!(a.data, b.data, "nkv={nkv}: post-rollback decode differs");
            // And against a cache that never held the rejected tail.
            let mut fresh = pool.new_cache();
            m.prefill_paged(&prompt, &mut fresh, &mut pool);
            m.verify_paged(&fed[..2], &mut fresh, &mut pool);
            let c = m.decode_batch_paged(&[19], std::slice::from_mut(&mut fresh), &mut pool);
            assert_eq!(a.data, c.data, "nkv={nkv}: rollback state is not clean");
            pool.release(&mut spec);
            pool.release(&mut seq);
            pool.release(&mut fresh);
            assert_eq!(pool.blocks_in_use(), 0);
        }
    }

    #[test]
    fn verify_paged_empty_is_empty() {
        let m = tiny_model(24, 4);
        let cfg = PoolConfig { block_size: 4, budget_blocks: 8, ..PoolConfig::default() };
        let mut pool = m.new_pool(&cfg, 1);
        let mut c = pool.new_cache();
        let out = m.verify_paged(&[], &mut c, &mut pool);
        assert_eq!((out.rows, out.cols), (0, m.cfg.vocab));
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn paged_decode_reads_shared_prefix_blocks() {
        // A request attached to another's prompt blocks decodes
        // exactly as if it had computed them itself.
        let m = tiny_model(14, 4);
        let cfg = PoolConfig { block_size: 4, budget_blocks: 32, ..PoolConfig::default() };
        let mut pool = m.new_pool(&cfg, 1);
        let prompt: Vec<u16> = vec![5, 9, 1, 30, 7, 2, 18, 4, 22];
        let mut a = pool.new_cache();
        let solo_logits = m.prefill_paged(&prompt, &mut a, &mut pool);
        pool.register_prompt_blocks(&a, &prompt);
        let mut b = pool.new_cache();
        let shared = pool.attach_prefix(&mut b, &prompt);
        assert_eq!(shared, 8, "two full blocks shared");
        let tail_logits = m.prefill_paged(&prompt[shared..], &mut b, &mut pool);
        assert_eq!(solo_logits, tail_logits, "shared-prefix prefill must be bit-identical");
        // And the next decoded token agrees with an unshared run.
        let la = m.decode_batch_paged(&[11], std::slice::from_mut(&mut a), &mut pool);
        let lb = m.decode_batch_paged(&[11], std::slice::from_mut(&mut b), &mut pool);
        assert_eq!(la.data, lb.data);
        pool.release(&mut a);
        pool.release(&mut b);
    }

    #[test]
    fn quantize_once_flags_set_and_bit_identical_to_per_linear() {
        use crate::quant::actquant::ActQuant;
        use crate::quant::binarize::BinaryLayer;
        let mut m = tiny_model(30, 4);
        let mut rng = Rng::new(31);
        let calib = Matrix::randn(32, m.cfg.d_model, &mut rng);
        for b in m.blocks.iter_mut() {
            for (name, lin) in b.linears_mut() {
                let w = lin.backend.reconstruct();
                let mut nl = Linear::new(Box::new(BinaryLayer::quantize(&w)));
                // wdown's input is d_ff-wide; keep it f32 so the test
                // also covers a mixed block.
                if name != "wdown" {
                    nl.act_quant = Some(ActQuant::calibrate(&calib, 8));
                }
                *lin = nl;
            }
        }
        m.prepare_engines();
        assert_eq!(m.blocks[0].qkv_share, Some(8));
        assert_eq!(m.blocks[0].ffn_share, Some(8));
        let tokens = [1u16, 5, 9, 22];
        let shared = m.forward(&tokens);
        let mut cache_s = m.new_cache(8);
        let shared_pre = m.prefill(&tokens, &mut cache_s);
        // Clearing the flags forces per-linear transform+quantize; the
        // outputs must not change by a single bit.
        for b in m.blocks.iter_mut() {
            b.qkv_share = None;
            b.ffn_share = None;
        }
        assert_eq!(m.forward(&tokens).data, shared.data);
        let mut cache_u = m.new_cache(8);
        assert_eq!(m.prefill(&tokens, &mut cache_u), shared_pre);
        // And the reference path clears the flags on its own.
        m.prepare_engines();
        m.cache_dense_all();
        assert!(m.blocks[0].qkv_share.is_none());
        assert!(m.blocks[0].ffn_share.is_none());
    }

    #[test]
    fn gqa_reduces_kv_dim() {
        let m = tiny_model(4, 2);
        assert_eq!(m.cfg.kv_dim(), 8);
        let logits = m.forward(&[1, 2, 3]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn capture_collects_all_sites() {
        let m = tiny_model(5, 4);
        let mut cap = Capture::new(64);
        let mut opt = Some(&mut cap);
        m.forward_capture(&[1, 2, 3, 4], &mut opt);
        for li in 0..2 {
            for site in [CaptureSite::Ln1Out, CaptureSite::AttnOut, CaptureSite::Ln2Out, CaptureSite::FfnMid] {
                let x = cap.matrix(li, site).unwrap();
                assert_eq!(x.rows, 4);
            }
        }
        // FfnMid has d_ff columns.
        assert_eq!(cap.matrix(0, CaptureSite::FfnMid).unwrap().cols, 24);
    }

    #[test]
    fn capture_respects_cap() {
        let m = tiny_model(6, 4);
        let mut cap = Capture::new(3);
        let mut opt = Some(&mut cap);
        m.forward_capture(&[1, 2, 3, 4, 5, 6], &mut opt);
        assert_eq!(cap.matrix(0, CaptureSite::Ln1Out).unwrap().rows, 3);
    }
}
