//! **`WeightBackend`** — the open weight-storage/compute trait that
//! every quantized-weight format implements, replacing the old closed
//! `LinearBackend` enum.
//!
//! A backend owns one weight matrix in some compressed representation
//! and answers for it end to end: reconstruction, the GEMM
//! (`matvec`, optionally via a prepared [`ComputeEngine`]), storage
//! accounting, and QLM1 serialization. Deserializers are looked up in a
//! process-wide registry keyed by the backend's stable [`tag`]
//! (`WeightBackend::tag`), so a new format added in one file — plus one
//! [`register_backend`] call — ships through `btc-llm quantize` →
//! `.qlm` → `btc-llm serve` without touching the container code.
//!
//! Built-in tags: `dense`, `binary`, `residual`, `nm-sparse`, `fp-vq`,
//! `codebook`. Tags are part of the QLM1 v2 on-disk format — never
//! reuse or rename a shipped tag.

use std::any::Any;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::sync::{Arc, OnceLock, RwLock};

use anyhow::Result;

use crate::engine::{ComputeEngine, EngineCtx};
use crate::io::wire;
use crate::quant::codebook::BinaryCodebook;
use crate::tensor::Matrix;

/// A pluggable weight storage/compute backend (one linear layer's
/// weight matrix in some — possibly compressed — representation).
pub trait WeightBackend: std::fmt::Debug + Send + Sync {
    /// Stable serialization tag, also the human-readable backend name.
    /// Part of the QLM1 on-disk format: never reuse or rename.
    fn tag(&self) -> &'static str;

    /// (out_features, in_features).
    fn shape(&self) -> (usize, usize);

    /// Dequantize to a dense matrix.
    fn reconstruct(&self) -> Matrix;

    /// y = x @ Ŵᵀ. The default dequantizes; backends with a native
    /// no-dequantization path override via [`make_engine`]
    /// (`WeightBackend::make_engine`) instead, which the [`super::Linear`]
    /// wrapper prepares once and reuses.
    fn matvec(&self, x: &Matrix) -> Matrix {
        x.matmul_bt(&self.reconstruct())
    }

    /// Weight storage bits (per-layer share; a shared codebook is
    /// counted separately by the memory accounting).
    fn storage_bits(&self) -> usize;

    /// Bytes this backend actually holds resident in RAM (owned buffer
    /// sizes, not the accounting convention). The default assumes the
    /// representation is as tight as [`storage_bits`]
    /// (`WeightBackend::storage_bits`) claims; backends whose in-memory
    /// buffers are wider (dense f32, unpacked masks, …) must override
    /// so the resident-vs-accounted truth gap stays visible in
    /// [`crate::eval::memory`].
    fn resident_bytes(&self) -> usize {
        self.storage_bits().div_ceil(8)
    }

    /// Bytes this backend's payload occupies on the QLM1 wire —
    /// measured by serializing into a counting sink, so it is exact by
    /// construction for any backend.
    fn wire_bytes(&self) -> usize {
        let mut cw = wire::CountingWriter::default();
        // A counting sink cannot fail; a backend that errors writes 0.
        let _ = self.write_payload(&mut cw);
        cw.bytes
    }

    /// Payload bits per weight: signs/indices/masks ONLY — the number
    /// the paper's tables report. Per-row fp16 scales are excluded
    /// because they amortize at real LLM widths (4096+ columns) but
    /// dominate at TinyLM widths; the full measured figure including
    /// scales is [`storage_bits`] (`WeightBackend::storage_bits`).
    fn payload_bits_per_weight(&self) -> f64;

    /// Build the backend's prepared serving engine, if it has one
    /// (sign-GEMM for binary, LUT-GEMM for codebook). `None` = the
    /// caller falls back to a cached dense reconstruction.
    fn make_engine(&self) -> Option<Box<dyn ComputeEngine>> {
        None
    }

    /// Like [`make_engine`] (`WeightBackend::make_engine`) but with an
    /// explicit [`EngineCtx`] (dispatch level, gather tile, activation
    /// quantization). The default ignores the ctx and delegates to
    /// `make_engine`, so third-party backends written against the old
    /// hook keep working unchanged; built-in backends override this
    /// one and route `make_engine` through it.
    fn make_engine_with(&self, ctx: &EngineCtx) -> Option<Box<dyn ComputeEngine>> {
        let _ = ctx;
        self.make_engine()
    }

    /// The shared binary codebook this backend references, if any
    /// (serialized once per QLM1 container, not per layer).
    fn shared_codebook(&self) -> Option<Arc<BinaryCodebook>> {
        None
    }

    /// Write the backend payload (everything needed to rebuild it,
    /// *except* a shared codebook, which the container carries once).
    fn write_payload(&self, w: &mut dyn Write) -> Result<()>;

    fn clone_box(&self) -> Box<dyn WeightBackend>;

    /// Downcasting escape hatch for format-specific tooling.
    fn as_any(&self) -> &dyn Any;
}

impl Clone for Box<dyn WeightBackend> {
    fn clone(&self) -> Box<dyn WeightBackend> {
        self.clone_box()
    }
}

/// Context handed to backend deserializers: container-level shared
/// state a per-layer payload may reference.
pub struct BackendIoCtx {
    /// The container's shared binary codebook (QLM1 header), if present.
    pub codebook: Option<Arc<BinaryCodebook>>,
    /// The container's QLM1 format version — lets a backend keep
    /// reading payload layouts from older containers (e.g. the
    /// codebook backend's v2 dense-u32 indices vs v3 packed planes).
    pub version: u32,
}

impl Default for BackendIoCtx {
    fn default() -> BackendIoCtx {
        BackendIoCtx { codebook: None, version: crate::io::qweights::QLM_VERSION }
    }
}

/// A registered payload deserializer: reads exactly the bytes written
/// by the matching [`WeightBackend::write_payload`].
pub type BackendReader = fn(&mut dyn Read, &BackendIoCtx) -> Result<Box<dyn WeightBackend>>;

fn registry() -> &'static RwLock<BTreeMap<String, BackendReader>> {
    static REG: OnceLock<RwLock<BTreeMap<String, BackendReader>>> = OnceLock::new();
    REG.get_or_init(|| {
        let mut m: BTreeMap<String, BackendReader> = BTreeMap::new();
        m.insert("dense".into(), read_dense as BackendReader);
        m.insert("binary".into(), crate::quant::binarize::read_backend);
        m.insert("residual".into(), crate::quant::arb::read_backend);
        m.insert("nm-sparse".into(), crate::quant::stbllm::read_backend);
        m.insert("fp-vq".into(), crate::quant::fpvq::read_backend);
        m.insert("codebook".into(), crate::quant::codebook::read_backend);
        RwLock::new(m)
    })
}

/// Register (or replace) a payload deserializer for `tag`. Built-in
/// tags are pre-registered; call this once per custom backend before
/// loading QLM1 files that contain it.
pub fn register_backend(tag: &str, reader: BackendReader) {
    registry().write().unwrap().insert(tag.to_string(), reader);
}

/// Look up the deserializer for a tag.
pub fn backend_reader(tag: &str) -> Option<BackendReader> {
    registry().read().unwrap().get(tag).copied()
}

/// All registered backend tags (diagnostics / error messages).
pub fn backend_tags() -> Vec<String> {
    registry().read().unwrap().keys().cloned().collect()
}

// ---- dense backend (fp32 matrix; the FP16 lane of the paper) ---------

impl WeightBackend for Matrix {
    fn tag(&self) -> &'static str {
        "dense"
    }

    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn reconstruct(&self) -> Matrix {
        self.clone()
    }

    fn matvec(&self, x: &Matrix) -> Matrix {
        x.matmul_bt(self)
    }

    fn storage_bits(&self) -> usize {
        self.data.len() * 16 // fp16 shipping convention
    }

    fn resident_bytes(&self) -> usize {
        self.data.len() * 4 // actually held as f32 (the honest number)
    }

    fn payload_bits_per_weight(&self) -> f64 {
        16.0
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        wire::w_u32(w, self.rows as u32)?;
        wire::w_u32(w, self.cols as u32)?;
        wire::w_f32s(w, &self.data)
    }

    fn clone_box(&self) -> Box<dyn WeightBackend> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

/// Deserializer for the `dense` tag.
pub fn read_dense(r: &mut dyn Read, _ctx: &BackendIoCtx) -> Result<Box<dyn WeightBackend>> {
    let rows = wire::r_u32(r)? as usize;
    let cols = wire::r_u32(r)? as usize;
    wire::check_dims("dense backend", rows, cols)?;
    Ok(Box::new(Matrix::from_vec(rows, cols, wire::r_f32s(r, rows * cols)?)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_backend_roundtrip() {
        let mut rng = Rng::new(1);
        let w = Matrix::randn(5, 7, &mut rng);
        let mut buf = Vec::new();
        w.write_payload(&mut buf).unwrap();
        let back = read_dense(&mut &buf[..], &BackendIoCtx::default()).unwrap();
        assert_eq!(back.tag(), "dense");
        assert_eq!(back.shape(), (5, 7));
        assert_eq!(back.reconstruct().data, w.data);
        assert_eq!(back.payload_bits_per_weight(), 16.0);
    }

    #[test]
    fn dense_resident_and_wire_bytes_are_measured() {
        let mut rng = Rng::new(2);
        let w = Matrix::randn(3, 4, &mut rng);
        // Resident: the actual f32 buffer (2x the fp16 accounting).
        assert_eq!(WeightBackend::resident_bytes(&w), 12 * 4);
        assert_eq!(WeightBackend::storage_bits(&w).div_ceil(8), 12 * 2);
        // Wire: rows + cols u32s then 12 f32s.
        assert_eq!(WeightBackend::wire_bytes(&w), 8 + 12 * 4);
    }

    #[test]
    fn registry_has_builtins_and_accepts_custom() {
        for tag in ["dense", "binary", "residual", "nm-sparse", "fp-vq", "codebook"] {
            assert!(backend_reader(tag).is_some(), "missing builtin {tag}");
        }
        fn toy(_r: &mut dyn Read, _c: &BackendIoCtx) -> Result<Box<dyn WeightBackend>> {
            Ok(Box::new(Matrix::zeros(1, 1)))
        }
        register_backend("toy-test-backend", toy);
        assert!(backend_reader("toy-test-backend").is_some());
        assert!(backend_tags().contains(&"toy-test-backend".to_string()));
    }
}
