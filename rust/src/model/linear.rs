//! Pluggable linear layer: one weight matrix behind a
//! [`WeightBackend`] trait object — the deployment surface of the
//! quantization pipeline. Any backend registered with
//! [`crate::model::register_backend`] plugs in here without changes.
//!
//! `forward` order: optional input transformation `x → xT` (the
//! learnable transformation of §4.2, applied online via Kronecker
//! factors) → activation quantization → the backend GEMM. With a
//! prepared integer-capable engine and `act_bits <= 8`, activation
//! quantization is *real*: rows become per-row int8 codes once and the
//! engine contracts them in i32 (W1A8, DESIGN.md §12). Otherwise the
//! per-channel [`ActQuant`] simulates quantization in f32 — that
//! sim-quant path is the accuracy reference for the integer lanes.
//!
//! For evaluation a reconstructed dense weight can be cached
//! (`cache_dense`) — numerically identical to the engine paths (the
//! engines are tested for exact agreement) but faster on the tiny-model
//! eval grid. Serving/latency benches run the real engines, prepared
//! from the backend via [`WeightBackend::make_engine_with`] with an
//! [`EngineCtx`] carrying the dispatch level, gather tile and act-quant.

use super::backend::WeightBackend;
use crate::engine::{Activations, ComputeEngine, EngineCtx, QuantizedActs};
use crate::quant::actquant::ActQuant;
use crate::quant::transform::Transform;
use crate::tensor::Matrix;

/// Compute path prepared lazily from the backend.
#[derive(Debug, Clone, Default)]
enum Engine {
    /// No preparation: dequantize through the backend on every call.
    #[default]
    None,
    /// Cached dense reconstruction (fast small-model evaluation).
    DenseCache(Matrix),
    /// The backend's own prepared serving engine.
    Prepared(Box<dyn ComputeEngine>),
}

/// A linear layer with backend, optional transform and act-quant.
#[derive(Debug, Clone)]
pub struct Linear {
    pub backend: Box<dyn WeightBackend>,
    /// Online input transformation (x → xT); `None` = identity.
    pub transform: Option<Transform>,
    /// Activation quantizer applied after the transform.
    pub act_quant: Option<ActQuant>,
    engine: Engine,
}

impl Linear {
    pub fn new(backend: Box<dyn WeightBackend>) -> Linear {
        Linear { backend, transform: None, act_quant: None, engine: Engine::None }
    }

    pub fn dense(w: Matrix) -> Linear {
        Self::new(Box::new(w))
    }

    pub fn out_features(&self) -> usize {
        self.backend.shape().0
    }

    pub fn in_features(&self) -> usize {
        self.backend.shape().1
    }

    /// Cache a reconstructed dense weight for fast evaluation.
    pub fn cache_dense(&mut self) {
        self.engine = Engine::DenseCache(self.backend.reconstruct());
    }

    /// Prepare the real serving engine for the backend (sign-GEMM for
    /// binary, LUT-GEMM for codebook; backends without a native engine
    /// fall back to a dense cache) using the process-current
    /// [`EngineCtx`] plus this linear's act-quant.
    pub fn prepare_engine(&mut self) {
        self.prepare_engine_with(&EngineCtx::current());
    }

    /// Prepare with an explicit [`EngineCtx`]; the linear's own
    /// act-quant is layered onto the ctx so the backend sees the full
    /// construction context.
    pub fn prepare_engine_with(&mut self, ctx: &EngineCtx) {
        let ctx = ctx.clone().with_act_quant(self.act_quant.clone());
        self.engine = match self.backend.make_engine_with(&ctx) {
            Some(e) => Engine::Prepared(e),
            None => Engine::DenseCache(self.backend.reconstruct()),
        };
    }

    /// Prepare an engine only if none is prepared yet (a cached dense
    /// reconstruction counts as prepared — the caller chose it).
    pub fn ensure_engine(&mut self) {
        if matches!(self.engine, Engine::None) {
            self.prepare_engine();
        }
    }

    /// The integer-path activation width: `Some(bits)` when a prepared
    /// engine will consume per-row int8 codes (act-quant configured at
    /// `bits <= 8`), `None` when forward runs the f32 sim-quant path.
    pub fn int_bits(&self) -> Option<u32> {
        match (&self.engine, &self.act_quant) {
            (Engine::Prepared(_), Some(aq)) if aq.bits <= 8 => Some(aq.bits),
            _ => None,
        }
    }

    /// y = f(x): transform → act-quant → GEMM. x: (m, in) -> (m, out).
    ///
    /// With a prepared engine and `act_bits <= 8` the rows are
    /// quantized to per-row int8 *once* and handed to the engine's
    /// integer lane; otherwise the per-channel [`ActQuant`] sim-quant
    /// runs in f32 (the accuracy reference).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut xt = match &self.transform {
            Some(t) => t.apply(x),
            None => x.clone(),
        };
        if let (Some(bits), Engine::Prepared(e)) = (self.int_bits(), &self.engine) {
            let qa = QuantizedActs::quantize(&xt, bits);
            return e.forward(&qa.as_acts());
        }
        if let Some(aq) = &self.act_quant {
            aq.apply(&mut xt);
        }
        match &self.engine {
            Engine::DenseCache(w) => xt.matmul_bt(w),
            Engine::Prepared(e) => e.forward(&Activations::F32(&xt)),
            Engine::None => self.backend.matvec(&xt),
        }
    }

    /// Forward from activations already quantized by the caller — the
    /// quantize-once seam: `transformer.rs` quantizes a block input a
    /// single time and feeds every linear in the site group (q/k/v,
    /// gate/up) the same codes. The caller is responsible for having
    /// applied this linear's transform first; engines without an
    /// integer lane (and the dense cache) consume the dequantized rows.
    pub fn forward_quantized(&self, qa: &QuantizedActs) -> Matrix {
        match &self.engine {
            Engine::Prepared(e) => e.forward(&qa.as_acts()),
            Engine::DenseCache(w) => qa.dequantize().matmul_bt(w),
            Engine::None => self.backend.matvec(&qa.dequantize()),
        }
    }

    /// Human-readable backend tag (logs/benches).
    pub fn backend_name(&self) -> &'static str {
        self.backend.tag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::binarize::BinaryLayer;
    use crate::util::proptest::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn dense_forward() {
        let mut r = Rng::new(1);
        let w = Matrix::randn(6, 8, &mut r);
        let lin = Linear::dense(w.clone());
        let x = Matrix::randn(3, 8, &mut r);
        assert_close(&lin.forward(&x).data, &x.matmul_bt(&w).data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn engine_paths_agree_with_reconstruct() {
        let mut r = Rng::new(2);
        let w = Matrix::randn(12, 32, &mut r);
        let x = Matrix::randn(2, 32, &mut r);
        let mut lin = Linear::new(Box::new(BinaryLayer::quantize(&w)));
        let lazy = lin.forward(&x);
        lin.prepare_engine();
        let engine = lin.forward(&x);
        lin.cache_dense();
        let cached = lin.forward(&x);
        assert_close(&lazy.data, &engine.data, 1e-3, 1e-3).unwrap();
        assert_close(&lazy.data, &cached.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn transform_plus_backend_composes() {
        // With a dense backend holding the *transformed* weight, the
        // transformed linear must reproduce the original product.
        let mut r = Rng::new(3);
        let dim = 8;
        let w = Matrix::randn(5, dim, &mut r);
        let mut t = Transform::identity(dim);
        t.sigma[3] = -1.0;
        t.p1 = Matrix::randn(t.p1.rows, t.p1.cols, &mut r);
        for i in 0..t.p1.rows {
            *t.p1.at_mut(i, i) += 3.0;
        }
        let wt = t.transform_weight(&w);
        let mut lin = Linear::dense(wt);
        lin.transform = Some(t);
        let x = Matrix::randn(4, dim, &mut r);
        assert_close(&lin.forward(&x).data, &x.matmul_bt(&w).data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn act_quant_applied() {
        let mut r = Rng::new(4);
        let w = Matrix::eye(4);
        let x = Matrix::randn(16, 4, &mut r);
        let mut lin = Linear::dense(w);
        lin.act_quant = Some(ActQuant::calibrate(&x, 4));
        let y = lin.forward(&x);
        // Output must be the quantized x (identity weight), not x.
        assert!(y.sub(&x).fro2() > 0.0);
    }

    #[test]
    fn int_path_engages_only_with_prepared_engine_and_low_bits() {
        let mut r = Rng::new(7);
        let w = Matrix::randn(12, 32, &mut r);
        let x = Matrix::randn(4, 32, &mut r);
        let mut lin = Linear::new(Box::new(BinaryLayer::quantize(&w)));
        lin.act_quant = Some(ActQuant::calibrate(&x, 8));
        assert!(lin.int_bits().is_none(), "no engine prepared yet");
        lin.prepare_engine();
        assert_eq!(lin.int_bits(), Some(8));
        lin.act_quant = Some(ActQuant::identity());
        assert!(lin.int_bits().is_none(), "16-bit identity must stay f32");
        lin.act_quant = None;
        assert!(lin.int_bits().is_none());
    }

    #[test]
    fn int_path_close_to_f32_engine_path() {
        // W1A8 through the integer lane vs the same engine fed f32:
        // per-row 8-bit dynamic quantization error only.
        let mut r = Rng::new(8);
        let w = Matrix::randn(24, 64, &mut r);
        let x = Matrix::randn(3, 64, &mut r);
        let mut lin = Linear::new(Box::new(BinaryLayer::quantize(&w)));
        lin.prepare_engine();
        let y_f32 = lin.forward(&x);
        lin.act_quant = Some(ActQuant::calibrate(&x, 8));
        lin.prepare_engine();
        assert_eq!(lin.int_bits(), Some(8));
        let y_int = lin.forward(&x);
        assert_close(&y_int.data, &y_f32.data, 5e-2, 1e-1).unwrap();
    }

    #[test]
    fn forward_quantized_bitwise_matches_internal_quantize() {
        // The quantize-once seam must be a pure refactor of forward:
        // same codes in, same bits out.
        let mut r = Rng::new(9);
        let w = Matrix::randn(12, 32, &mut r);
        let x = Matrix::randn(4, 32, &mut r);
        let mut lin = Linear::new(Box::new(BinaryLayer::quantize(&w)));
        lin.act_quant = Some(ActQuant::calibrate(&x, 8));
        lin.prepare_engine();
        let qa = crate::engine::QuantizedActs::quantize(&x, 8);
        assert_eq!(lin.forward(&x).data, lin.forward_quantized(&qa).data);
    }

    #[test]
    fn storage_bits_ordering() {
        let mut r = Rng::new(5);
        let w = Matrix::randn(32, 64, &mut r);
        let dense = Linear::dense(w.clone()).backend.storage_bits();
        let binary = Linear::new(Box::new(BinaryLayer::quantize(&w))).backend.storage_bits();
        assert!(binary < dense / 8, "binary {binary} vs dense {dense}");
    }

    #[test]
    fn backend_name_is_stable_tag() {
        let mut r = Rng::new(6);
        let w = Matrix::randn(4, 8, &mut r);
        assert_eq!(Linear::dense(w.clone()).backend_name(), "dense");
        assert_eq!(
            Linear::new(Box::new(BinaryLayer::quantize(&w))).backend_name(),
            "binary"
        );
    }
}
