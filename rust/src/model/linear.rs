//! Pluggable linear layer: one weight matrix, many storage/compute
//! backends. The deployment surface of the quantization pipeline.
//!
//! `forward` order: optional input transformation `x → xT` (the
//! learnable transformation of §4.2, applied online via Kronecker
//! factors) → optional activation quantization (Table 3d) → the
//! backend GEMM.
//!
//! For evaluation a reconstructed dense weight can be cached
//! (`cache_dense`) — numerically identical to the engine paths (the
//! engines are tested for exact agreement) but faster on the tiny-model
//! eval grid. Serving/latency benches run the real engines.

use crate::engine::{BinaryGemmEngine, LutGemmEngine};
use crate::quant::actquant::ActQuant;
use crate::quant::arb::ResidualBinary;
use crate::quant::binarize::BinaryLayer;
use crate::quant::codebook::CodebookLayer;
use crate::quant::fpvq::FpVqLayer;
use crate::quant::stbllm::NmSparseBinary;
use crate::quant::transform::Transform;
use crate::tensor::Matrix;

/// Weight storage/compute backends.
#[derive(Debug, Clone)]
pub enum LinearBackend {
    /// fp32 dense (the FP16 lane of the paper's tables).
    Dense(Matrix),
    /// Binarized (W1A16 sign-GEMM engine).
    Binary(BinaryLayer),
    /// Salient residual binarization (BiLLM / ARB-LLM lanes).
    Residual(ResidualBinary),
    /// N:M structured sparse binary (STBLLM lane).
    NmSparse(NmSparseBinary),
    /// FP vector quantization (GPTVQ/VPTQ lane).
    FpVq(FpVqLayer),
    /// Binary codebook (the BTC sub-1-bit lane, LUT-GEMM engine).
    Codebook(CodebookLayer),
}

impl LinearBackend {
    pub fn reconstruct(&self) -> Matrix {
        match self {
            LinearBackend::Dense(w) => w.clone(),
            LinearBackend::Binary(b) => b.reconstruct(),
            LinearBackend::Residual(r) => r.reconstruct(),
            LinearBackend::NmSparse(s) => s.reconstruct(),
            LinearBackend::FpVq(v) => v.reconstruct(),
            LinearBackend::Codebook(c) => c.reconstruct(),
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        match self {
            LinearBackend::Dense(w) => (w.rows, w.cols),
            LinearBackend::Binary(b) => (b.rows, b.cols),
            LinearBackend::Residual(r) => (r.primary.rows, r.primary.cols),
            LinearBackend::NmSparse(s) => (s.rows, s.cols),
            LinearBackend::FpVq(v) => (v.rows, v.cols),
            LinearBackend::Codebook(c) => (c.rows, c.cols),
        }
    }

    /// Weight storage bits (per-layer share; shared codebook counted
    /// separately by the memory accounting).
    pub fn storage_bits(&self) -> usize {
        match self {
            LinearBackend::Dense(w) => w.data.len() * 16, // fp16 convention
            LinearBackend::Binary(b) => b.storage_bits(),
            LinearBackend::Residual(r) => r.storage_bits(),
            LinearBackend::NmSparse(s) => s.storage_bits(),
            LinearBackend::FpVq(v) => v.storage_bits(),
            LinearBackend::Codebook(c) => c.storage_bits(),
        }
    }

    /// Payload bits per weight: signs/indices/masks ONLY — the number
    /// the paper's tables report. Per-row fp16 scales are excluded
    /// because they amortize at real LLM widths (4096+ columns) but
    /// dominate at TinyLM widths; the full measured figure including
    /// scales is `storage_bits()`.
    pub fn payload_bits_per_weight(&self) -> f64 {
        let (o, i) = self.shape();
        let n = (o * i) as f64;
        match self {
            LinearBackend::Dense(_) => 16.0,
            LinearBackend::Binary(b) => {
                let group = if b.n_groups > 1 {
                    b.cols * (usize::BITS - (b.n_groups - 1).leading_zeros()) as usize
                } else {
                    0
                };
                (b.rows * b.cols + group) as f64 / n
            }
            LinearBackend::Residual(r) => {
                let p = &r.primary;
                let group = if p.n_groups > 1 {
                    p.cols * (usize::BITS - (p.n_groups - 1).leading_zeros()) as usize
                } else {
                    0
                };
                // primary signs + residual signs on salient cols + bitmap
                (p.rows * p.cols + r.residual.rows * r.residual.cols + p.cols + group) as f64 / n
            }
            LinearBackend::NmSparse(s) => {
                let mask = 64
                    - (crate::quant::stbllm::binom(s.m as u64, s.n as u64).saturating_sub(1))
                        .leading_zeros() as usize;
                (s.n + mask) as f64 / s.m as f64
            }
            LinearBackend::FpVq(v) => {
                let idx_bits = (usize::BITS - (v.c - 1).leading_zeros()) as f64;
                idx_bits * v.idx.len() as f64 / n
            }
            LinearBackend::Codebook(c) => {
                c.codebook.index_bits() as f64 * c.idx.len() as f64 / n
            }
        }
    }
}

/// Compute engines prepared lazily from the backend.
#[derive(Debug, Clone, Default)]
enum Engine {
    #[default]
    None,
    DenseCache(Matrix),
    Xnor(BinaryGemmEngine),
    Lut(LutGemmEngine),
}

/// A linear layer with backend, optional transform and act-quant.
#[derive(Debug, Clone)]
pub struct Linear {
    pub backend: LinearBackend,
    /// Online input transformation (x → xT); `None` = identity.
    pub transform: Option<Transform>,
    /// Activation quantizer applied after the transform.
    pub act_quant: Option<ActQuant>,
    engine: Engine,
}

impl Linear {
    pub fn new(backend: LinearBackend) -> Linear {
        Linear { backend, transform: None, act_quant: None, engine: Engine::None }
    }

    pub fn dense(w: Matrix) -> Linear {
        Self::new(LinearBackend::Dense(w))
    }

    pub fn out_features(&self) -> usize {
        self.backend.shape().0
    }

    pub fn in_features(&self) -> usize {
        self.backend.shape().1
    }

    /// Cache a reconstructed dense weight for fast evaluation.
    pub fn cache_dense(&mut self) {
        self.engine = Engine::DenseCache(self.backend.reconstruct());
    }

    /// Prepare the real serving engine for the backend (sign-GEMM for
    /// binary, LUT-GEMM for codebook; others fall back to dense cache).
    pub fn prepare_engine(&mut self) {
        self.engine = match &self.backend {
            LinearBackend::Binary(b) => Engine::Xnor(BinaryGemmEngine::new(b)),
            LinearBackend::Codebook(c) => match LutGemmEngine::try_new(c) {
                Some(e) => Engine::Lut(e),
                None => Engine::DenseCache(self.backend.reconstruct()),
            },
            _ => Engine::DenseCache(self.backend.reconstruct()),
        };
    }

    /// y = f(x): transform → act-quant → GEMM. x: (m, in) -> (m, out).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut xt = match &self.transform {
            Some(t) => t.apply(x),
            None => x.clone(),
        };
        if let Some(aq) = &self.act_quant {
            aq.apply(&mut xt);
        }
        match &self.engine {
            Engine::DenseCache(w) => xt.matmul_bt(w),
            Engine::Xnor(e) => e.forward(&xt),
            Engine::Lut(e) => e.forward(&xt),
            Engine::None => xt.matmul_bt(&self.backend.reconstruct()),
        }
    }

    /// Human-readable backend tag (logs/benches).
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            LinearBackend::Dense(_) => "dense",
            LinearBackend::Binary(_) => "binary",
            LinearBackend::Residual(_) => "residual",
            LinearBackend::NmSparse(_) => "nm-sparse",
            LinearBackend::FpVq(_) => "fp-vq",
            LinearBackend::Codebook(_) => "codebook",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn dense_forward() {
        let mut r = Rng::new(1);
        let w = Matrix::randn(6, 8, &mut r);
        let lin = Linear::dense(w.clone());
        let x = Matrix::randn(3, 8, &mut r);
        assert_close(&lin.forward(&x).data, &x.matmul_bt(&w).data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn engine_paths_agree_with_reconstruct() {
        let mut r = Rng::new(2);
        let w = Matrix::randn(12, 32, &mut r);
        let x = Matrix::randn(2, 32, &mut r);
        let mut lin = Linear::new(LinearBackend::Binary(BinaryLayer::quantize(&w)));
        let lazy = lin.forward(&x);
        lin.prepare_engine();
        let engine = lin.forward(&x);
        lin.cache_dense();
        let cached = lin.forward(&x);
        assert_close(&lazy.data, &engine.data, 1e-3, 1e-3).unwrap();
        assert_close(&lazy.data, &cached.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn transform_plus_backend_composes() {
        // With a dense backend holding the *transformed* weight, the
        // transformed linear must reproduce the original product.
        let mut r = Rng::new(3);
        let dim = 8;
        let w = Matrix::randn(5, dim, &mut r);
        let mut t = Transform::identity(dim);
        t.sigma[3] = -1.0;
        t.p1 = Matrix::randn(t.p1.rows, t.p1.cols, &mut r);
        for i in 0..t.p1.rows {
            *t.p1.at_mut(i, i) += 3.0;
        }
        let wt = t.transform_weight(&w);
        let mut lin = Linear::dense(wt);
        lin.transform = Some(t);
        let x = Matrix::randn(4, dim, &mut r);
        assert_close(&lin.forward(&x).data, &x.matmul_bt(&w).data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn act_quant_applied() {
        let mut r = Rng::new(4);
        let w = Matrix::eye(4);
        let x = Matrix::randn(16, 4, &mut r);
        let mut lin = Linear::dense(w);
        lin.act_quant = Some(ActQuant::calibrate(&x, 4));
        let y = lin.forward(&x);
        // Output must be the quantized x (identity weight), not x.
        assert!(y.sub(&x).fro2() > 0.0);
    }

    #[test]
    fn storage_bits_ordering() {
        let mut r = Rng::new(5);
        let w = Matrix::randn(32, 64, &mut r);
        let dense = Linear::dense(w.clone()).backend.storage_bits();
        let binary = LinearBackend::Binary(BinaryLayer::quantize(&w)).storage_bits();
        assert!(binary < dense / 8, "binary {binary} vs dense {dense}");
    }
}
