//! Pluggable linear layer: one weight matrix behind a
//! [`WeightBackend`] trait object — the deployment surface of the
//! quantization pipeline. Any backend registered with
//! [`crate::model::register_backend`] plugs in here without changes.
//!
//! `forward` order: optional input transformation `x → xT` (the
//! learnable transformation of §4.2, applied online via Kronecker
//! factors) → optional activation quantization (Table 3d) → the
//! backend GEMM.
//!
//! For evaluation a reconstructed dense weight can be cached
//! (`cache_dense`) — numerically identical to the engine paths (the
//! engines are tested for exact agreement) but faster on the tiny-model
//! eval grid. Serving/latency benches run the real engines, prepared
//! from the backend via [`WeightBackend::make_engine`].

use super::backend::WeightBackend;
use crate::engine::ComputeEngine;
use crate::quant::actquant::ActQuant;
use crate::quant::transform::Transform;
use crate::tensor::Matrix;

/// Compute path prepared lazily from the backend.
#[derive(Debug, Clone, Default)]
enum Engine {
    /// No preparation: dequantize through the backend on every call.
    #[default]
    None,
    /// Cached dense reconstruction (fast small-model evaluation).
    DenseCache(Matrix),
    /// The backend's own prepared serving engine.
    Prepared(Box<dyn ComputeEngine>),
}

/// A linear layer with backend, optional transform and act-quant.
#[derive(Debug, Clone)]
pub struct Linear {
    pub backend: Box<dyn WeightBackend>,
    /// Online input transformation (x → xT); `None` = identity.
    pub transform: Option<Transform>,
    /// Activation quantizer applied after the transform.
    pub act_quant: Option<ActQuant>,
    engine: Engine,
}

impl Linear {
    pub fn new(backend: Box<dyn WeightBackend>) -> Linear {
        Linear { backend, transform: None, act_quant: None, engine: Engine::None }
    }

    pub fn dense(w: Matrix) -> Linear {
        Self::new(Box::new(w))
    }

    pub fn out_features(&self) -> usize {
        self.backend.shape().0
    }

    pub fn in_features(&self) -> usize {
        self.backend.shape().1
    }

    /// Cache a reconstructed dense weight for fast evaluation.
    pub fn cache_dense(&mut self) {
        self.engine = Engine::DenseCache(self.backend.reconstruct());
    }

    /// Prepare the real serving engine for the backend (sign-GEMM for
    /// binary, LUT-GEMM for codebook; backends without a native engine
    /// fall back to a dense cache).
    pub fn prepare_engine(&mut self) {
        self.engine = match self.backend.make_engine() {
            Some(e) => Engine::Prepared(e),
            None => Engine::DenseCache(self.backend.reconstruct()),
        };
    }

    /// Prepare an engine only if none is prepared yet (a cached dense
    /// reconstruction counts as prepared — the caller chose it).
    pub fn ensure_engine(&mut self) {
        if matches!(self.engine, Engine::None) {
            self.prepare_engine();
        }
    }

    /// y = f(x): transform → act-quant → GEMM. x: (m, in) -> (m, out).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut xt = match &self.transform {
            Some(t) => t.apply(x),
            None => x.clone(),
        };
        if let Some(aq) = &self.act_quant {
            aq.apply(&mut xt);
        }
        match &self.engine {
            Engine::DenseCache(w) => xt.matmul_bt(w),
            Engine::Prepared(e) => e.forward(&xt),
            Engine::None => self.backend.matvec(&xt),
        }
    }

    /// Human-readable backend tag (logs/benches).
    pub fn backend_name(&self) -> &'static str {
        self.backend.tag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::binarize::BinaryLayer;
    use crate::util::proptest::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn dense_forward() {
        let mut r = Rng::new(1);
        let w = Matrix::randn(6, 8, &mut r);
        let lin = Linear::dense(w.clone());
        let x = Matrix::randn(3, 8, &mut r);
        assert_close(&lin.forward(&x).data, &x.matmul_bt(&w).data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn engine_paths_agree_with_reconstruct() {
        let mut r = Rng::new(2);
        let w = Matrix::randn(12, 32, &mut r);
        let x = Matrix::randn(2, 32, &mut r);
        let mut lin = Linear::new(Box::new(BinaryLayer::quantize(&w)));
        let lazy = lin.forward(&x);
        lin.prepare_engine();
        let engine = lin.forward(&x);
        lin.cache_dense();
        let cached = lin.forward(&x);
        assert_close(&lazy.data, &engine.data, 1e-3, 1e-3).unwrap();
        assert_close(&lazy.data, &cached.data, 1e-5, 1e-5).unwrap();
    }

    #[test]
    fn transform_plus_backend_composes() {
        // With a dense backend holding the *transformed* weight, the
        // transformed linear must reproduce the original product.
        let mut r = Rng::new(3);
        let dim = 8;
        let w = Matrix::randn(5, dim, &mut r);
        let mut t = Transform::identity(dim);
        t.sigma[3] = -1.0;
        t.p1 = Matrix::randn(t.p1.rows, t.p1.cols, &mut r);
        for i in 0..t.p1.rows {
            *t.p1.at_mut(i, i) += 3.0;
        }
        let wt = t.transform_weight(&w);
        let mut lin = Linear::dense(wt);
        lin.transform = Some(t);
        let x = Matrix::randn(4, dim, &mut r);
        assert_close(&lin.forward(&x).data, &x.matmul_bt(&w).data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn act_quant_applied() {
        let mut r = Rng::new(4);
        let w = Matrix::eye(4);
        let x = Matrix::randn(16, 4, &mut r);
        let mut lin = Linear::dense(w);
        lin.act_quant = Some(ActQuant::calibrate(&x, 4));
        let y = lin.forward(&x);
        // Output must be the quantized x (identity weight), not x.
        assert!(y.sub(&x).fro2() > 0.0);
    }

    #[test]
    fn storage_bits_ordering() {
        let mut r = Rng::new(5);
        let w = Matrix::randn(32, 64, &mut r);
        let dense = Linear::dense(w.clone()).backend.storage_bits();
        let binary = Linear::new(Box::new(BinaryLayer::quantize(&w))).backend.storage_bits();
        assert!(binary < dense / 8, "binary {binary} vs dense {dense}");
    }

    #[test]
    fn backend_name_is_stable_tag() {
        let mut r = Rng::new(6);
        let w = Matrix::randn(4, 8, &mut r);
        assert_eq!(Linear::dense(w.clone()).backend_name(), "dense");
        assert_eq!(
            Linear::new(Box::new(BinaryLayer::quantize(&w))).backend_name(),
            "binary"
        );
    }
}
