//! Rotary position embeddings, split-half convention — bit-compatible
//! with `python/compile/model.py::apply_rope` (first half = real part,
//! second half = imaginary part).

/// Precomputed cos/sin tables for positions `0..max_seq`.
#[derive(Debug, Clone)]
pub struct Rope {
    pub head_dim: usize,
    /// (max_seq, head_dim/2) each.
    pub cos: Vec<f32>,
    pub sin: Vec<f32>,
    pub max_seq: usize,
}

impl Rope {
    pub fn new(head_dim: usize, max_seq: usize, theta: f32) -> Rope {
        assert!(head_dim % 2 == 0, "head_dim must be even for RoPE");
        let half = head_dim / 2;
        let mut cos = Vec::with_capacity(max_seq * half);
        let mut sin = Vec::with_capacity(max_seq * half);
        for pos in 0..max_seq {
            for k in 0..half {
                let inv = (theta as f64).powf(-((2 * k) as f64) / head_dim as f64);
                let ang = pos as f64 * inv;
                cos.push(ang.cos() as f32);
                sin.push(ang.sin() as f32);
            }
        }
        Rope { head_dim, cos, sin, max_seq }
    }

    /// Rotate one head vector in place at position `pos`.
    pub fn apply(&self, x: &mut [f32], pos: usize) {
        debug_assert_eq!(x.len(), self.head_dim);
        assert!(pos < self.max_seq, "position {pos} beyond rope table");
        let half = self.head_dim / 2;
        let (c, s) = (&self.cos[pos * half..(pos + 1) * half], &self.sin[pos * half..(pos + 1) * half]);
        for k in 0..half {
            let (x1, x2) = (x[k], x[k + half]);
            x[k] = x1 * c[k] - x2 * s[k];
            x[k + half] = x1 * s[k] + x2 * c[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn position_zero_is_identity() {
        let rope = Rope::new(8, 16, 10000.0);
        let mut r = Rng::new(1);
        let orig = r.normal_vec(8);
        let mut x = orig.clone();
        rope.apply(&mut x, 0);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn preserves_norm() {
        let rope = Rope::new(16, 32, 10000.0);
        let mut r = Rng::new(2);
        for pos in [1, 5, 31] {
            let orig = r.normal_vec(16);
            let mut x = orig.clone();
            rope.apply(&mut x, pos);
            let n0: f32 = orig.iter().map(|v| v * v).sum();
            let n1: f32 = x.iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() < 1e-4 * n0.max(1.0));
        }
    }

    #[test]
    fn relative_rotation_composes() {
        // Rotating by pos a then checking the angle difference between
        // consecutive positions is constant per frequency.
        let rope = Rope::new(4, 8, 100.0);
        // freq 0 angle at pos p is p * theta^0 = p.
        let a1 = (rope.cos[1 * 2], rope.sin[1 * 2]);
        let a2 = (rope.cos[2 * 2], rope.sin[2 * 2]);
        // cos(2) == cos(1+1) = c1c1 - s1s1
        assert!((a2.0 - (a1.0 * a1.0 - a1.1 * a1.1)).abs() < 1e-5);
    }

    #[test]
    #[should_panic(expected = "beyond rope table")]
    fn out_of_range_position_panics() {
        let rope = Rope::new(4, 4, 100.0);
        let mut x = vec![0.0; 4];
        rope.apply(&mut x, 4);
    }
}
