//! **`PackedPlane`** — a dense plane of k-bit unsigned integers
//! (1 <= k <= 32), the storage substrate that makes codebook index
//! planes *actually* sub-byte in RAM (paper's "eliminates sparse
//! masks" memory claim, §4.1/App. H).
//!
//! Layout: row-major; each row is an independent little-endian
//! bitstream padded to whole u64 words, so row starts are word-aligned
//! and rows can be decoded independently (the LUT-GEMM gather decodes
//! one block-row tile at a time). Elements may straddle a word
//! boundary inside a row (k <= 32, so at most two words).
//!
//! The wire format is *tighter* than this in-memory layout: QLM1 v3
//! serializes planes as unpadded bitstreams via
//! [`crate::io::wire::w_bits`] / [`crate::io::wire::r_bits`], so row
//! padding never reaches disk.

/// A bit-packed matrix of k-bit unsigned values with word-aligned rows.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedPlane {
    pub rows: usize,
    pub cols: usize,
    /// Bits per element (1..=32).
    pub k: usize,
    pub words_per_row: usize,
    pub data: Vec<u64>,
}

impl PackedPlane {
    /// All-zero plane. `k` must be in 1..=32.
    pub fn zeros(rows: usize, cols: usize, k: usize) -> PackedPlane {
        assert!((1..=32).contains(&k), "PackedPlane element width {k} out of 1..=32");
        let wpr = (cols * k).div_ceil(64);
        PackedPlane { rows, cols, k, words_per_row: wpr, data: vec![0; rows * wpr] }
    }

    /// Pack row-major values. Every value must fit in `k` bits.
    pub fn from_u32s(rows: usize, cols: usize, k: usize, values: &[u32]) -> PackedPlane {
        assert_eq!(values.len(), rows * cols, "value count != rows*cols");
        let mut p = Self::zeros(rows, cols, k);
        for r in 0..rows {
            for c in 0..cols {
                p.set(r, c, values[r * cols + c]);
            }
        }
        p
    }

    /// Number of logical elements.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    fn mask(&self) -> u64 {
        (1u64 << self.k) - 1
    }

    #[inline]
    fn row_words(&self, r: usize) -> &[u64] {
        &self.data[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> u32 {
        debug_assert!(r < self.rows && c < self.cols);
        let row = self.row_words(r);
        let bit = c * self.k;
        let (w, off) = (bit >> 6, bit & 63);
        let mut v = row[w] >> off;
        if off + self.k > 64 {
            v |= row[w + 1] << (64 - off);
        }
        (v & self.mask()) as u32
    }

    pub fn set(&mut self, r: usize, c: usize, v: u32) {
        let k = self.k;
        let mask = self.mask();
        assert!((v as u64) <= mask, "value {v} does not fit in {k} bits");
        debug_assert!(r < self.rows && c < self.cols);
        let base = r * self.words_per_row;
        let bit = c * k;
        let (w, off) = (bit >> 6, bit & 63);
        self.data[base + w] = (self.data[base + w] & !(mask << off)) | ((v as u64) << off);
        if off + k > 64 {
            let lo = 64 - off; // bits already placed in the first word
            let w2 = &mut self.data[base + w + 1];
            *w2 = (*w2 & !(mask >> lo)) | ((v as u64) >> lo);
        }
    }

    /// Bulk-decode elements `c0..c0+out.len()` of row `r` into a
    /// caller-provided (typically stack) buffer — the hot-path
    /// accessor: one running bit cursor, no per-element div/mod.
    #[inline]
    pub fn decode_range(&self, r: usize, c0: usize, out: &mut [u32]) {
        debug_assert!(c0 + out.len() <= self.cols, "decode_range out of bounds");
        let k = self.k;
        let mask = self.mask();
        let row = self.row_words(r);
        let mut bit = c0 * k;
        for o in out.iter_mut() {
            let (w, off) = (bit >> 6, bit & 63);
            let mut v = row[w] >> off;
            if off + k > 64 {
                v |= row[w + 1] << (64 - off);
            }
            *o = (v & mask) as u32;
            bit += k;
        }
    }

    /// Decode one whole row.
    pub fn decode_row(&self, r: usize) -> Vec<u32> {
        let mut out = vec![0u32; self.cols];
        self.decode_range(r, 0, &mut out);
        out
    }

    /// Decode the whole plane row-major.
    pub fn to_u32s(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.len());
        for r in 0..self.rows {
            out.extend(self.decode_row(r));
        }
        out
    }

    /// Decode the whole plane row-major, widened to u64 (the shape the
    /// generic packed wire writer takes).
    pub fn to_u64s(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.len());
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.push(self.get(r, c) as u64);
            }
        }
        out
    }

    /// Transposed copy (rows x cols -> cols x rows, same k) — used to
    /// build the LUT-GEMM engine's block-major index plane from a
    /// layer's row-major one.
    pub fn transposed(&self) -> PackedPlane {
        let mut t = Self::zeros(self.cols, self.rows, self.k);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.set(c, r, self.get(r, c));
            }
        }
        t
    }

    /// Actually-resident bytes of the packed buffer.
    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_property_all_widths() {
        check(
            "plane pack/unpack roundtrip",
            40,
            |r: &mut Rng| {
                let k = 1 + r.below(32);
                let rows = 1 + r.below(6);
                let cols = 1 + r.below(40);
                let cap = if k == 32 { u64::from(u32::MAX) + 1 } else { 1u64 << k };
                let vals: Vec<u32> =
                    (0..rows * cols).map(|_| (r.next_u64() % cap) as u32).collect();
                (rows, cols, k, vals)
            },
            |(rows, cols, k, vals)| {
                let p = PackedPlane::from_u32s(*rows, *cols, *k, vals);
                if &p.to_u32s() == vals { Ok(()) } else { Err("roundtrip mismatch".into()) }
            },
        );
    }

    #[test]
    fn straddles_word_boundaries() {
        // k=13 makes elements cross u64 boundaries inside a row.
        let vals: Vec<u32> = (0..20).map(|i| (i * 397) % (1 << 13)).collect();
        let p = PackedPlane::from_u32s(2, 10, 13, &vals);
        assert_eq!(p.words_per_row, 3); // 130 bits -> 3 words
        for r in 0..2 {
            for c in 0..10 {
                assert_eq!(p.get(r, c), vals[r * 10 + c], "({r},{c})");
            }
        }
    }

    #[test]
    fn decode_range_matches_get() {
        let mut rng = Rng::new(7);
        let vals: Vec<u32> = (0..3 * 33).map(|_| (rng.next_u64() % (1 << 11)) as u32).collect();
        let p = PackedPlane::from_u32s(3, 33, 11, &vals);
        for c0 in [0usize, 1, 7, 30] {
            let n = 33 - c0;
            let mut buf = vec![0u32; n];
            p.decode_range(1, c0, &mut buf);
            for (i, &b) in buf.iter().enumerate() {
                assert_eq!(b, p.get(1, c0 + i), "c0={c0} i={i}");
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(8);
        let vals: Vec<u32> = (0..5 * 9).map(|_| (rng.next_u64() % (1 << 6)) as u32).collect();
        let p = PackedPlane::from_u32s(5, 9, 6, &vals);
        let t = p.transposed();
        assert_eq!((t.rows, t.cols), (9, 5));
        for r in 0..5 {
            for c in 0..9 {
                assert_eq!(t.get(c, r), p.get(r, c));
            }
        }
        assert_eq!(t.transposed(), p);
    }

    #[test]
    fn set_overwrites_cleanly() {
        let mut p = PackedPlane::zeros(1, 8, 5);
        p.set(0, 3, 0b11111);
        p.set(0, 3, 0b01010);
        assert_eq!(p.get(0, 3), 0b01010);
        assert_eq!(p.get(0, 2), 0);
        assert_eq!(p.get(0, 4), 0);
    }

    #[test]
    fn rows_are_word_aligned() {
        // 3 cols x 5 bits = 15 bits/row -> 1 word/row; rows independent.
        let p = PackedPlane::from_u32s(2, 3, 5, &[1, 2, 3, 29, 30, 31]);
        assert_eq!(p.words_per_row, 1);
        assert_eq!(p.data.len(), 2);
        assert_eq!(p.decode_row(0), vec![1, 2, 3]);
        assert_eq!(p.decode_row(1), vec![29, 30, 31]);
    }

    #[test]
    fn storage_accounting() {
        let p = PackedPlane::zeros(10, 100, 13); // 1300 bits -> 21 words/row
        assert_eq!(p.storage_bytes(), 10 * 21 * 8);
        assert_eq!(p.len(), 1000);
    }
}
