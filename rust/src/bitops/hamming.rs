//! XOR + POPCNT Hamming-distance kernels (paper Eq. 4-5).
//!
//! For ±1 vectors packed as bits, squared Euclidean distance reduces to
//! `4 · d_H` and the inner product to `len − 2 · d_H` — one XOR and one
//! POPCNT per 64 elements instead of 64 multiply-adds.

/// Hamming distance between two packed rows of `n_bits` valid bits.
/// `tail_mask` masks the final word's padding (see BitMatrix::tail_mask).
#[inline]
pub fn hamming_words(a: &[u64], b: &[u64], tail_mask: u64) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let last = a.len() - 1;
    let mut d = 0u32;
    for i in 0..last {
        d += (a[i] ^ b[i]).count_ones();
    }
    d + ((a[last] ^ b[last]) & tail_mask).count_ones()
}

/// Hamming distance between two ±1 f32 slices (reference path).
pub fn hamming(a: &[f32], b: &[f32]) -> u32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| (**x >= 0.0) != (**y >= 0.0)).count() as u32
}

/// Inner product of two packed ±1 rows: `<a,b> = n − 2·d_H(a,b)`.
#[inline]
pub fn xnor_dot(a: &[u64], b: &[u64], n_bits: usize, tail_mask: u64) -> i32 {
    n_bits as i32 - 2 * hamming_words(a, b, tail_mask) as i32
}

/// Squared Euclidean distance between ±1 vectors: `4·d_H` (paper Eq. 4).
#[inline]
pub fn sq_euclidean(a: &[u64], b: &[u64], tail_mask: u64) -> u32 {
    4 * hamming_words(a, b, tail_mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::pack::{pack_signs, BitMatrix};
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn hamming_known() {
        let a = [1.0, 1.0, -1.0, -1.0];
        let b = [1.0, -1.0, -1.0, 1.0];
        assert_eq!(hamming(&a, &b), 2);
    }

    #[test]
    fn packed_matches_naive_property() {
        check(
            "hamming packed == naive",
            50,
            |r: &mut Rng| {
                let n = 1 + r.below(200);
                let a: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                let b: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                (a, b)
            },
            |(a, b)| {
                let m = BitMatrix::from_signs(2, a.len(), &[a.clone(), b.clone()].concat());
                let packed = hamming_words(m.row(0), m.row(1), m.tail_mask());
                let naive = hamming(a, b);
                if packed == naive {
                    Ok(())
                } else {
                    Err(format!("{packed} != {naive}"))
                }
            },
        );
    }

    #[test]
    fn xnor_dot_matches_fp_dot_property() {
        check(
            "xnor_dot == fp dot",
            50,
            |r: &mut Rng| {
                let n = 1 + r.below(130);
                let a: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                let b: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                (a, b)
            },
            |(a, b)| {
                let pa = pack_signs(a);
                let pb = pack_signs(b);
                let mask = if a.len() % 64 == 0 { u64::MAX } else { (1u64 << (a.len() % 64)) - 1 };
                let fast = xnor_dot(&pa, &pb, a.len(), mask);
                let fp: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                if fast == fp as i32 {
                    Ok(())
                } else {
                    Err(format!("{fast} != {fp}"))
                }
            },
        );
    }

    #[test]
    fn sq_euclidean_is_4x_hamming() {
        let a = pack_signs(&[1.0, -1.0, 1.0]);
        let b = pack_signs(&[-1.0, -1.0, -1.0]);
        assert_eq!(sq_euclidean(&a, &b, 0b111), 8);
    }

    #[test]
    fn identical_vectors_distance_zero() {
        let a = pack_signs(&[1.0; 100]);
        assert_eq!(hamming_words(&a, &a, (1u64 << 36) - 1), 0);
    }

    #[test]
    fn padding_bits_ignored() {
        // 3 valid bits; poison a padding bit in one operand's copy.
        let mut a = pack_signs(&[1.0, 1.0, 1.0]);
        let b = pack_signs(&[1.0, 1.0, 1.0]);
        a[0] |= 1u64 << 40; // padding
        assert_eq!(hamming_words(&a, &b, 0b111), 0);
    }
}
