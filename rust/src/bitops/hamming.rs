//! XOR + POPCNT Hamming-distance kernels (paper Eq. 4-5).
//!
//! For ±1 vectors packed as bits, squared Euclidean distance reduces to
//! `4 · d_H` and the inner product to `len − 2 · d_H` — one XOR and one
//! POPCNT per 64 elements instead of 64 multiply-adds.
//!
//! The word loop dispatches through [`crate::util::simd`]: the scalar
//! body is the oracle, and the AVX2/NEON wrappers recompile the *same*
//! unrolled body under wider target features so LLVM emits vector
//! `popcnt` sequences (Harley-Seal-style on AVX2, `vcnt`+`vaddv` on
//! NEON). Popcount is integer arithmetic, so every lane is
//! **bit-identical** to scalar — asserted by the forced-variant
//! equivalence suite (`rust/tests/simd_equivalence.rs`).
//!
//! Two tail policies exist:
//! - [`hamming_words`] masks the final word with `tail_mask` and is
//!   safe for operands with arbitrary padding bits.
//! - [`hamming_words_padded`] assumes *clean* padding (the
//!   `BitMatrix::from_signs` invariant, checkable via
//!   `BitMatrix::padding_clean`) and runs one uniform unmasked loop —
//!   the shape the vector lane wants and a small scalar win on
//!   non-multiple-of-64 widths.

use crate::util::simd::{self, Level};

/// Sum of `popcount(a[i] ^ b[i])` over full words, 4-way unrolled with
/// independent counters so the feature-gated wrappers vectorize it.
#[inline(always)]
fn xor_popcnt_generic(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut c0, mut c1, mut c2, mut c3) = (0u32, 0u32, 0u32, 0u32);
    for i in 0..chunks {
        let j = i * 4;
        c0 += (a[j] ^ b[j]).count_ones();
        c1 += (a[j + 1] ^ b[j + 1]).count_ones();
        c2 += (a[j + 2] ^ b[j + 2]).count_ones();
        c3 += (a[j + 3] ^ b[j + 3]).count_ones();
    }
    let mut tail = 0u32;
    for j in chunks * 4..a.len() {
        tail += (a[j] ^ b[j]).count_ones();
    }
    (c0 + c1) + (c2 + c3) + tail
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and POPCNT (guaranteed
    /// by dispatching on [`crate::util::simd::Level`]).
    #[target_feature(enable = "avx2,popcnt")]
    pub unsafe fn xor_popcnt(a: &[u64], b: &[u64]) -> u32 {
        super::xor_popcnt_generic(a, b)
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    /// # Safety
    /// Caller must ensure the CPU supports NEON (guaranteed by
    /// dispatching on [`crate::util::simd::Level`]).
    #[target_feature(enable = "neon")]
    pub unsafe fn xor_popcnt(a: &[u64], b: &[u64]) -> u32 {
        super::xor_popcnt_generic(a, b)
    }
}

/// Full-word XOR+POPCNT at an explicit dispatch level (integer math —
/// bit-identical across every level).
#[inline]
fn xor_popcnt_words(level: Level, a: &[u64], b: &[u64]) -> u32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 | Level::Avx512 => unsafe { x86::xor_popcnt(a, b) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { arm::xor_popcnt(a, b) },
        _ => xor_popcnt_generic(a, b),
    }
}

/// Hamming distance between two packed rows of `n_bits` valid bits.
/// `tail_mask` masks the final word's padding (see BitMatrix::tail_mask).
#[inline]
pub fn hamming_words(a: &[u64], b: &[u64], tail_mask: u64) -> u32 {
    hamming_words_with_level(simd::active(), a, b, tail_mask)
}

/// [`hamming_words`] at an explicit dispatch level (for the
/// equivalence suite; results are bit-identical across levels).
#[inline]
pub fn hamming_words_with_level(level: Level, a: &[u64], b: &[u64], tail_mask: u64) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    let last = a.len() - 1;
    xor_popcnt_words(level, &a[..last], &b[..last])
        + ((a[last] ^ b[last]) & tail_mask).count_ones()
}

/// Hamming distance between packed rows whose padding bits are already
/// zero (the `BitMatrix::from_signs` invariant): one uniform unmasked
/// loop, no per-row tail special-casing. Callers with possibly-dirty
/// words must use [`hamming_words`] instead.
#[inline]
pub fn hamming_words_padded(a: &[u64], b: &[u64]) -> u32 {
    hamming_words_padded_with_level(simd::active(), a, b)
}

/// [`hamming_words_padded`] at an explicit dispatch level.
#[inline]
pub fn hamming_words_padded_with_level(level: Level, a: &[u64], b: &[u64]) -> u32 {
    xor_popcnt_words(level, a, b)
}

/// Hamming distance between two ±1 f32 slices (reference path).
pub fn hamming(a: &[f32], b: &[f32]) -> u32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).filter(|(x, y)| (**x >= 0.0) != (**y >= 0.0)).count() as u32
}

/// Inner product of two packed ±1 rows: `<a,b> = n − 2·d_H(a,b)`.
#[inline]
pub fn xnor_dot(a: &[u64], b: &[u64], n_bits: usize, tail_mask: u64) -> i32 {
    n_bits as i32 - 2 * hamming_words(a, b, tail_mask) as i32
}

/// Squared Euclidean distance between ±1 vectors: `4·d_H` (paper Eq. 4).
#[inline]
pub fn sq_euclidean(a: &[u64], b: &[u64], tail_mask: u64) -> u32 {
    4 * hamming_words(a, b, tail_mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::pack::{pack_signs, BitMatrix};
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn hamming_known() {
        let a = [1.0, 1.0, -1.0, -1.0];
        let b = [1.0, -1.0, -1.0, 1.0];
        assert_eq!(hamming(&a, &b), 2);
    }

    #[test]
    fn packed_matches_naive_property() {
        check(
            "hamming packed == naive",
            50,
            |r: &mut Rng| {
                let n = 1 + r.below(200);
                let a: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                let b: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                (a, b)
            },
            |(a, b)| {
                let m = BitMatrix::from_signs(2, a.len(), &[a.clone(), b.clone()].concat());
                let packed = hamming_words(m.row(0), m.row(1), m.tail_mask());
                let naive = hamming(a, b);
                if packed == naive {
                    Ok(())
                } else {
                    Err(format!("{packed} != {naive}"))
                }
            },
        );
    }

    #[test]
    fn xnor_dot_matches_fp_dot_property() {
        check(
            "xnor_dot == fp dot",
            50,
            |r: &mut Rng| {
                let n = 1 + r.below(130);
                let a: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                let b: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                (a, b)
            },
            |(a, b)| {
                let pa = pack_signs(a);
                let pb = pack_signs(b);
                let mask = if a.len() % 64 == 0 { u64::MAX } else { (1u64 << (a.len() % 64)) - 1 };
                let fast = xnor_dot(&pa, &pb, a.len(), mask);
                let fp: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                if fast == fp as i32 {
                    Ok(())
                } else {
                    Err(format!("{fast} != {fp}"))
                }
            },
        );
    }

    #[test]
    fn sq_euclidean_is_4x_hamming() {
        let a = pack_signs(&[1.0, -1.0, 1.0]);
        let b = pack_signs(&[-1.0, -1.0, -1.0]);
        assert_eq!(sq_euclidean(&a, &b, 0b111), 8);
    }

    #[test]
    fn identical_vectors_distance_zero() {
        let a = pack_signs(&[1.0; 100]);
        assert_eq!(hamming_words(&a, &a, (1u64 << 36) - 1), 0);
    }

    #[test]
    fn padding_bits_ignored() {
        // 3 valid bits; poison a padding bit in one operand's copy.
        let mut a = pack_signs(&[1.0, 1.0, 1.0]);
        let b = pack_signs(&[1.0, 1.0, 1.0]);
        a[0] |= 1u64 << 40; // padding
        assert_eq!(hamming_words(&a, &b, 0b111), 0);
    }

    #[test]
    fn padded_variant_matches_masked_on_clean_padding() {
        check(
            "padded == masked when padding clean",
            50,
            |r: &mut Rng| {
                let n = 1 + r.below(300);
                let a: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                let b: Vec<f32> = (0..n).map(|_| r.sign()).collect();
                (a, b)
            },
            |(a, b)| {
                let pa = pack_signs(a);
                let pb = pack_signs(b);
                let mask = if a.len() % 64 == 0 { u64::MAX } else { (1u64 << (a.len() % 64)) - 1 };
                let masked = hamming_words(&pa, &pb, mask);
                let padded = hamming_words_padded(&pa, &pb);
                if masked == padded {
                    Ok(())
                } else {
                    Err(format!("masked {masked} != padded {padded}"))
                }
            },
        );
    }

    #[test]
    fn every_supported_level_bit_identical() {
        let mut r = Rng::new(0x5EED);
        for n in [1usize, 63, 64, 65, 127, 128, 191, 200, 513] {
            let a: Vec<f32> = (0..n).map(|_| r.sign()).collect();
            let b: Vec<f32> = (0..n).map(|_| r.sign()).collect();
            let pa = pack_signs(&a);
            let pb = pack_signs(&b);
            let mask = if n % 64 == 0 { u64::MAX } else { (1u64 << (n % 64)) - 1 };
            let oracle = hamming_words_with_level(Level::Scalar, &pa, &pb, mask);
            let oracle_pad = hamming_words_padded_with_level(Level::Scalar, &pa, &pb);
            for l in simd::supported_levels() {
                assert_eq!(hamming_words_with_level(l, &pa, &pb, mask), oracle, "n={n} {l:?}");
                assert_eq!(
                    hamming_words_padded_with_level(l, &pa, &pb),
                    oracle_pad,
                    "padded n={n} {l:?}"
                );
            }
        }
    }
}
