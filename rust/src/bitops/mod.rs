//! Bit-level substrate for binary weights: ±1 ↔ packed-u64 conversion
//! and XOR/POPCNT Hamming kernels (paper Eq. 4-5, Alg. 3).

pub mod hamming;
pub mod pack;

pub use hamming::{hamming, hamming_words, xnor_dot};
pub use pack::BitMatrix;
