//! Bit-level substrate for binary weights: ±1 ↔ packed-u64 conversion,
//! XOR/POPCNT Hamming kernels (paper Eq. 4-5, Alg. 3), and the k-bit
//! [`PackedPlane`] behind sub-byte codebook index storage.

pub mod hamming;
pub mod pack;
pub mod plane;

pub use hamming::{hamming, hamming_words, hamming_words_padded, xnor_dot};
pub use pack::BitMatrix;
pub use plane::PackedPlane;
