//! ±1 ↔ packed-u64 bit conversion.
//!
//! Convention (shared with `python/compile/kernels/lut_gemm.py`):
//! bit = 1 encodes +1, bit = 0 encodes −1; element `i` of a vector maps
//! to bit `i % 64` of word `i / 64` (little-endian bit order).

/// Pack a ±1 f32 slice into u64 words. Values must be exactly ±1
/// (zero is treated as +1, matching the paper's sign(0)=+1 rule).
pub fn pack_signs(signs: &[f32]) -> Vec<u64> {
    let nwords = signs.len().div_ceil(64);
    let mut words = vec![0u64; nwords];
    for (i, &s) in signs.iter().enumerate() {
        if s >= 0.0 {
            words[i / 64] |= 1u64 << (i % 64);
        }
    }
    words
}

/// Unpack u64 words into n ±1 f32 values.
pub fn unpack_signs(words: &[u64], n: usize) -> Vec<f32> {
    assert!(words.len() * 64 >= n, "not enough words");
    (0..n)
        .map(|i| if words[i / 64] >> (i % 64) & 1 == 1 { 1.0 } else { -1.0 })
        .collect()
}

/// A bit-packed ±1 matrix: `rows` rows, each `cols` bits padded to
/// whole u64 words. Padding bits are ZERO (i.e. decode as −1) and must
/// never be included in distance computations — [`crate::bitops::hamming`]
/// masks them via `Self::tail_mask`.
#[derive(Clone, Debug, PartialEq)]
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    pub words_per_row: usize,
    pub data: Vec<u64>,
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let wpr = cols.div_ceil(64);
        BitMatrix { rows, cols, words_per_row: wpr, data: vec![0; rows * wpr] }
    }

    /// Pack from a row-major ±1 f32 matrix slice.
    pub fn from_signs(rows: usize, cols: usize, signs: &[f32]) -> Self {
        assert_eq!(rows * cols, signs.len());
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            let packed = pack_signs(&signs[r * cols..(r + 1) * cols]);
            let off = r * m.words_per_row;
            m.data[off..off + m.words_per_row].copy_from_slice(&packed);
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[u64] {
        &self.data[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [u64] {
        &mut self.data[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Decode row r to ±1 f32.
    pub fn unpack_row(&self, r: usize) -> Vec<f32> {
        unpack_signs(self.row(r), self.cols)
    }

    /// Decode the whole matrix row-major.
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            out.extend(self.unpack_row(r));
        }
        out
    }

    /// Mask selecting the valid bits of the LAST word of a row
    /// (all-ones when cols is a multiple of 64).
    #[inline]
    pub fn tail_mask(&self) -> u64 {
        let rem = self.cols % 64;
        if rem == 0 {
            u64::MAX
        } else {
            (1u64 << rem) - 1
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        if self.row(r)[c / 64] >> (c % 64) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, plus: bool) {
        let wpr = self.words_per_row;
        let w = &mut self.data[r * wpr + c / 64];
        if plus {
            *w |= 1u64 << (c % 64);
        } else {
            *w &= !(1u64 << (c % 64));
        }
    }

    /// Storage in bytes (the real memory-accounting number).
    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 8
    }

    /// True when every row's padding bits (past `cols` in its last
    /// word) are zero — the invariant `from_signs`/`set` maintain and
    /// the unmasked [`crate::bitops::hamming::hamming_words_padded`]
    /// fast path relies on. O(rows); debug-assert material.
    pub fn padding_clean(&self) -> bool {
        let poison = !self.tail_mask();
        if poison == 0 {
            return true;
        }
        (0..self.rows).all(|r| self.row(r)[self.words_per_row - 1] & poison == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn pack_unpack_roundtrip_property() {
        check(
            "pack/unpack roundtrip",
            50,
            |r: &mut Rng| {
                let n = 1 + r.below(200);
                (0..n).map(|_| r.sign()).collect::<Vec<f32>>()
            },
            |signs| {
                let words = pack_signs(signs);
                let back = unpack_signs(&words, signs.len());
                if &back == signs { Ok(()) } else { Err("roundtrip mismatch".into()) }
            },
        );
    }

    #[test]
    fn zero_maps_to_plus_one() {
        let words = pack_signs(&[0.0, -1.0, 1.0]);
        assert_eq!(unpack_signs(&words, 3), vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn bitmatrix_roundtrip_property() {
        check(
            "bitmatrix roundtrip",
            30,
            |r: &mut Rng| {
                let rows = 1 + r.below(8);
                let cols = 1 + r.below(150);
                let signs: Vec<f32> = (0..rows * cols).map(|_| r.sign()).collect();
                (rows, cols, signs)
            },
            |(rows, cols, signs)| {
                let m = BitMatrix::from_signs(*rows, *cols, signs);
                if &m.unpack() == signs { Ok(()) } else { Err("mismatch".into()) }
            },
        );
    }

    #[test]
    fn get_set() {
        let mut m = BitMatrix::zeros(3, 70);
        assert_eq!(m.get(2, 69), -1.0);
        m.set(2, 69, true);
        assert_eq!(m.get(2, 69), 1.0);
        m.set(2, 69, false);
        assert_eq!(m.get(2, 69), -1.0);
    }

    #[test]
    fn tail_mask_values() {
        assert_eq!(BitMatrix::zeros(1, 64).tail_mask(), u64::MAX);
        assert_eq!(BitMatrix::zeros(1, 3).tail_mask(), 0b111);
        assert_eq!(BitMatrix::zeros(1, 65).tail_mask(), 1);
    }

    #[test]
    fn padding_clean_tracks_poisoned_bits() {
        let mut m = BitMatrix::from_signs(2, 70, &[1.0; 140]);
        assert!(m.padding_clean());
        m.data[3] |= 1u64 << 63; // row 1, padding region (bits 6..64 of last word)
        assert!(!m.padding_clean());
        // Full-word widths have no padding to poison.
        assert!(BitMatrix::from_signs(2, 64, &[-1.0; 128]).padding_clean());
    }

    #[test]
    fn storage_accounting() {
        let m = BitMatrix::zeros(10, 100); // 2 words/row
        assert_eq!(m.storage_bytes(), 10 * 2 * 8);
    }
}
