//! `btc-llm` launcher: the L3 CLI.
//!
//! ```text
//! btc-llm info      [--model tinylm_m]                  model + memory report
//! btc-llm quantize  [--model tinylm_m] [--method btc] [--bits 0.8] [--out m.qlm]
//! btc-llm eval      [--model tinylm_m] [--method btc] [--bits 0.8] [--tokens 4096] [--zeroshot]
//! btc-llm serve     [--config configs/serve.toml] [--requests 16] [--threads N] [--kv-bits B]
//!                   [--act-bits B] [--listen ADDR] [--smoke] [--synthetic]
//!                   [--tuning-file tuning.toml] [--autotune]
//!                   [--draft-model m.qlm] [--spec-k K]
//! btc-llm parity                                        PJRT artifact cross-check
//! ```
//!
//! With `--listen ADDR` (or `[serve] listen` in the config) the serve
//! command starts the TCP front-end (`coordinator/net.rs`) instead of
//! replaying an offline trace; `--smoke` then runs one loopback
//! streamed request and exits (the CI smoke), and `--synthetic` swaps
//! the artifact model for a hermetic random one so the smoke needs no
//! `make artifacts`.

use anyhow::{Context, Result};
use btc_llm::coordinator::{NetOptions, NetServer, ServeConfig, Server, ServerOptions, SpecConfig};
use btc_llm::data::{corpus, ByteTokenizer};
use btc_llm::eval::{memory, perplexity, zeroshot};
use btc_llm::io::{load_model, qweights};
use btc_llm::model::Transformer;
use btc_llm::quant::pipeline::{quantize_model, registry, QuantConfig};
use btc_llm::runtime::{PjrtRuntime, TensorArg};
use btc_llm::util::argparse::Args;
use btc_llm::{artifacts_dir, info};

/// Resolve `--method NAME [--bits B]` through the method registry.
/// NAME may itself carry a bits suffix (`--method btc-0.8`).
fn method_config(args: &Args) -> Result<QuantConfig> {
    let spec = args.get_or("method", "btc");
    let bits = args.get("bits").map(|b| b.parse::<f64>()).transpose().context("--bits")?;
    let mut cfg = registry::get_with_bits(spec, bits)?;
    if let Some(v) = args.get("v") {
        cfg.v = v.parse().context("--v")?;
    }
    if let Some(a) = args.get("act-bits") {
        cfg.act_bits = a.parse().context("--act-bits")?;
    }
    cfg.n_splits = args.get_usize("splits", cfg.n_splits);
    Ok(cfg)
}

fn load_raw(args: &Args) -> Result<(String, btc_llm::io::RawModel, Vec<u8>)> {
    let name = args.get_or("model", "tinylm_m").to_string();
    let dir = artifacts_dir();
    let raw = load_model(&dir.join(format!("{name}.bin")))
        .with_context(|| format!("load {name}.bin — run `make artifacts` first"))?;
    let corpus_bytes = std::fs::read(dir.join("corpus_eval.txt")).context("corpus_eval.txt")?;
    Ok((name, raw, corpus_bytes))
}

fn cmd_info(args: &Args) -> Result<()> {
    let (name, raw, _) = load_raw(args)?;
    let model = Transformer::from_raw(&raw)?;
    let r = memory::report(&model);
    println!("model {name}: {} params", raw.config.param_count());
    println!(
        "  d_model={} layers={} heads={}/{} d_ff={} vocab={}",
        raw.config.d_model, raw.config.n_layer, raw.config.n_head, raw.config.n_kv_head,
        raw.config.d_ff, raw.config.vocab
    );
    println!("  fp16 size: {}", memory::human_bytes(r.fp16_total_bytes));
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let (name, raw, corpus_bytes) = load_raw(args)?;
    let cfg = method_config(args)?;
    let display: &str = registry::display_name(&cfg.method).unwrap_or(cfg.method.as_str());
    info!("quantizing {name} with {display} @ {} bits", cfg.target_bits);
    let qm = quantize_model(&raw, &corpus_bytes, &cfg)?;
    let r = memory::report(&qm.model);
    println!(
        "{} @ {:.2} bits: measured {:.3} bits/weight, rel err {:.4}, {} -> {} ({:.1}x)",
        qm.stats.method,
        qm.stats.target_bits,
        r.linear_bits_per_weight,
        qm.stats.mean_rel_error,
        memory::human_bytes(r.fp16_total_bytes),
        memory::human_bytes(r.total_bytes),
        r.compression
    );
    if let Some(out) = args.get("out") {
        qweights::save(std::path::Path::new(out), &qm.model)?;
        println!("saved quantized model to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (name, raw, corpus_bytes) = load_raw(args)?;
    let cfg = method_config(args)?;
    let qm = quantize_model(&raw, &corpus_bytes, &cfg)?;
    let tok = ByteTokenizer::default();
    let text = String::from_utf8_lossy(&corpus_bytes).into_owned();
    let tokens = tok.encode(&text);
    let max_tokens = args.get_usize("tokens", 4096);
    let ppl = perplexity::perplexity(&qm.model, &tokens, 96, max_tokens);
    println!("{name} {} @ {:.2}b: ppl {:.3}", qm.stats.method, qm.stats.target_bits, ppl);
    if args.flag("zeroshot") {
        let (per_task, mean) = zeroshot::run_all(&qm.model, args.get_usize("examples", 40), 7);
        for (t, a) in &per_task {
            println!("  {t:<10} {a:.1}%");
        }
        println!("  mean {mean:.2}%");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ServeConfig::from_file(std::path::Path::new(path))
            .map_err(|e| anyhow::anyhow!("config: {e}"))?,
        None => ServeConfig::default(),
    };
    // CLI override for the kernel worker count (0 = auto; the server
    // validates/clamps and the effective value is reported below).
    cfg.threads = args.get_usize("threads", cfg.threads);
    // CLI override for KV-cache quantization: `--kv-bits 4` packs cold
    // cache blocks to int4 (+f16 row scales); 0 or >= 16 (the
    // default) keeps the cache f32 and outputs bit-identical. 9..=15
    // have no storage format and snap down to 8.
    cfg.kv_bits = btc_llm::quant::kvquant::KvQuantConfig::sanitize_bits(
        args.get_usize("kv-bits", cfg.kv_bits as usize) as u32,
    );
    // CLI override for engine-boundary activation quantization:
    // `--act-bits 8` arms the per-row W1A8 integer lanes; 0 or >= 16
    // (the default) keeps activations f32. Same clamp convention as
    // --kv-bits.
    cfg.act_bits = btc_llm::quant::kvquant::KvQuantConfig::sanitize_bits(
        args.get_usize("act-bits", cfg.act_bits as usize) as u32,
    );
    // CLI overrides for speculative decoding: `--draft-model PATH`
    // points at a QLM1 artifact (e.g. a btc-0.8 quantization of the
    // same checkpoint), `--spec-k K` sets the initial draft length.
    // Raising k past the configured ceiling lifts the ceiling too, so
    // `--spec-k 10` alone is not an instant start-time error.
    if let Some(p) = args.get("draft-model") {
        cfg.draft_model = p.to_string();
    }
    cfg.spec_k = args.get_usize("spec-k", cfg.spec_k);
    cfg.spec_max_k = cfg.spec_max_k.max(cfg.spec_k);
    if let Some(addr) = args.get("listen") {
        addr.parse::<std::net::SocketAddr>()
            .map_err(|e| anyhow::anyhow!("--listen {addr}: {e}"))?;
        cfg.listen = Some(addr.to_string());
    }
    // Kernel tuning: a persisted autotuner file first, then (or
    // instead) the quick in-process sweep. Both only retune
    // speed-shaping knobs — results are pinned bit-identical across
    // tile widths and thread splits, so a stale file cannot corrupt
    // outputs. CLI: `--tuning-file PATH` / `--autotune`.
    let tuning_file = args.get_or("tuning-file", &cfg.tuning_file).to_string();
    if !tuning_file.is_empty() {
        let t = btc_llm::util::autotune::Tuning::from_file(&tuning_file)
            .map_err(|e| anyhow::anyhow!("tuning file: {e}"))?;
        t.apply();
        // The file's prefill chunk applies only where the config left
        // the default — an explicit `[serve] prefill_chunk` wins.
        if cfg.prefill_chunk == ServeConfig::default().prefill_chunk {
            cfg.prefill_chunk = t.prefill_chunk;
        }
        info!("tuning file {tuning_file}: {}", t.summary());
    }
    if cfg.autotune || args.flag("autotune") {
        info!("autotuning kernels (quick sweep)...");
        let rep = btc_llm::util::autotune::run(true);
        rep.tuning.apply();
        if cfg.prefill_chunk == ServeConfig::default().prefill_chunk {
            cfg.prefill_chunk = rep.tuning.prefill_chunk;
        }
        info!("autotune: {}", rep.tuning.summary());
    }
    let (raw, corpus_bytes) = if args.flag("synthetic") {
        // Hermetic: a random model of a serving-representative shape,
        // so the loopback smoke runs without `make artifacts`.
        use btc_llm::io::weights::ModelConfig;
        btc_llm::util::fixture::synth_raw_model(
            11,
            ModelConfig {
                vocab: 192,
                d_model: 96,
                n_layer: 2,
                n_head: 6,
                n_kv_head: 3,
                d_ff: 192,
                max_seq: 160,
                rope_theta: 10000.0,
            },
        )
    } else {
        let dir = artifacts_dir();
        let raw = load_model(&dir.join(format!("{}.bin", cfg.model)))?;
        let corpus_bytes = std::fs::read(dir.join("corpus_eval.txt"))?;
        (raw, corpus_bytes)
    };
    // The serve config names a method by registry key ("binary" is the
    // historical alias for the ARB-LLM binary lane). A bits suffix in
    // the spec itself (backend = "btc-0.5") wins over the separate
    // `bits` key, which otherwise applies.
    let spec = match cfg.backend.as_str() {
        "binary" => "arb-llm",
        other => other,
    };
    let mut qcfg = registry::get_with_fallback_bits(spec, Some(cfg.bits))?;
    // Serving quantizes weights here; activation width is the serve
    // knob (`[serve] act_bits` / `--act-bits`), calibrated per-row at
    // run time by the engines, so the pipeline's calibration pass
    // stays off.
    qcfg.act_bits = 16;
    info!("quantizing {} for serving ({})", cfg.model, cfg.backend);
    let qm = quantize_model(&raw, &corpus_bytes, &qcfg)?;
    // try_start prepares any missing engines itself; the config also
    // carries the scheduler/QoS knobs (prefill chunk, stop set,
    // tenant table, admission/eviction policy). A bad QoS table is an
    // error here, not a worker-thread panic.
    let mut opts = ServerOptions::from(&cfg);
    // The draft model rides the same raw checkpoint: the QLM1 header
    // self-validates shape, so a wrong/corrupt/missing file is an
    // error here — before the worker thread exists.
    if !cfg.draft_model.is_empty() {
        opts.spec = Some(
            SpecConfig::load(
                std::path::Path::new(&cfg.draft_model),
                &raw,
                cfg.spec_k,
                cfg.spec_max_k,
            )
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        );
    }
    let server = Server::try_start_with_opts(qm.model, opts)
        .map_err(|e| anyhow::anyhow!("start server: {e}"))?;
    info!(
        "serving with {} kernel thread(s), act_bits={} simd={} spec={} gather_tile={} \
         par_min_work={} prefill_chunk={}",
        server.threads,
        cfg.act_bits,
        btc_llm::util::simd::active().name(),
        server.metrics.spec_label(),
        btc_llm::util::autotune::gather_tile(),
        btc_llm::util::parallel::par_min_work(),
        cfg.prefill_chunk
    );
    if let Some(addr) = cfg.listen.clone() {
        return serve_network(server, &addr, args.flag("smoke"));
    }
    // Replay a request trace (no listener configured; the trace IS the
    // workload — see examples/serve.rs for the full driver).
    let n = args.get_usize("requests", 16);
    let tok = ByteTokenizer::default();
    let prompts = corpus::prompts(n, cfg.seed);
    let rxs = prompts
        .iter()
        .map(|p| server.submit(tok.encode(p), cfg.max_new_tokens, cfg.temperature))
        .collect::<Result<Vec<_>, _>>()
        .context("server rejected a request")?;
    for (p, rx) in prompts.iter().zip(rxs) {
        let resp = rx.recv().expect("response");
        println!(
            "'{p}' -> '{}' ({} tok, ttft {:.1} ms, {:.1} ms)",
            tok.decode(&resp.tokens[resp.prompt_len..]).trim_end(),
            resp.tokens.len() - resp.prompt_len,
            resp.ttft.as_secs_f64() * 1e3,
            resp.latency.as_secs_f64() * 1e3
        );
    }
    println!("{}", server.metrics.summary());
    server.shutdown();
    Ok(())
}

/// Run the TCP front-end. With `smoke` set, issue one loopback
/// streamed request against ourselves and exit non-zero unless the
/// full SSE round-trip works — this is the CI serve-smoke step.
fn serve_network(server: Server, addr: &str, smoke: bool) -> Result<()> {
    use std::io::{Read, Write};
    let server = std::sync::Arc::new(server);
    let net = NetServer::bind(server, addr, NetOptions::default())
        .map_err(|e| anyhow::anyhow!("listen {addr}: {e}"))?;
    let bound = net.local_addr();
    if smoke {
        let mut conn = std::net::TcpStream::connect(bound).context("smoke connect")?;
        let body = r#"{"prompt":[10,20,30],"max_new":8,"stream":true}"#;
        write!(
            conn,
            "POST /generate HTTP/1.1\r\nHost: smoke\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )?;
        let mut reply = String::new();
        conn.read_to_string(&mut reply).context("smoke read")?;
        net.shutdown(std::time::Duration::from_secs(5));
        anyhow::ensure!(reply.contains("200 OK"), "smoke: bad status:\n{reply}");
        anyhow::ensure!(reply.contains("data: {\"token\""), "smoke: no token events:\n{reply}");
        anyhow::ensure!(reply.contains("\"done\":true"), "smoke: no final event:\n{reply}");
        println!("serve smoke OK: streamed tokens over loopback from {bound}");
        return Ok(());
    }
    println!("listening on http://{bound} (POST /generate, GET /healthz, GET /metrics)");
    println!("press enter to drain and exit");
    let mut line = String::new();
    let _ = std::io::stdin().read_line(&mut line);
    net.shutdown(std::time::Duration::from_secs(30));
    Ok(())
}

fn cmd_parity(_args: &Args) -> Result<()> {
    let dir = artifacts_dir();
    let mut rt = PjrtRuntime::cpu(&dir)?;
    println!("platform: {}", rt.platform());
    // Smoke: run the binary_gemm kernel artifact on fixed inputs.
    let (m, n, o) = (8usize, 96usize, 64usize);
    let x = TensorArg::F32(vec![m, n], (0..m * n).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect());
    let b = TensorArg::F32(vec![o, n], (0..o * n).map(|i| if i % 3 == 0 { 1.0 } else { -1.0 }).collect());
    let alpha = TensorArg::F32(vec![o], vec![0.5; o]);
    let mu = TensorArg::F32(vec![o], vec![0.01; o]);
    let out = rt.run_f32("binary_gemm.hlo.txt", &[x, b, alpha, mu])?;
    println!("binary_gemm artifact: {} outputs, first={:.4}", out.len(), out[0]);
    println!("parity OK (full cross-check: examples/hlo_parity.rs)");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("parity") => cmd_parity(&args),
        _ => {
            println!(
                "btc-llm — sub-1-bit LLM quantization (BTC-LLM reproduction)\n\
                 usage: btc-llm <info|quantize|eval|serve|parity> [--model NAME] \
                 [--method SPEC] [--bits B] ...\n\
                 methods: {} (SPEC may carry a bits suffix, e.g. btc-0.8)",
                registry::names().join("|")
            );
            Ok(())
        }
    }
}
