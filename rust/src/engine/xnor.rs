//! Sign-GEMM engine over bit-packed ±1 weights (paper Fig. 5, 1-bit
//! lane): `y[i,r] = Σ_g alpha[r,g]·Σ_{c∈g} ±x[i,c] + mu[r]·Σx`.
//!
//! No dequantized weight is ever materialized: the ±1 contraction uses
//! the identity `Σ ±x = 2·Σ_{bits set} x − Σ x`. Two activation lanes:
//!
//! - **W1A16 (f32)**: the scalar lane walks the *set* bits of each
//!   64-column word (≈ cols/2 adds) and is the oracle; the AVX2 lane
//!   instead turns each sign byte into an 8-lane compare mask and does
//!   a masked vector accumulate (8 adds per 8 columns, no
//!   data-dependent branching), which reassociates the sum — so the
//!   f32 vector lanes are ULP-bounded rather than bit-identical
//!   against scalar (bound asserted in
//!   `rust/tests/simd_equivalence.rs`).
//! - **W1A8 (int8)**: per-row int8 activations contracted entirely in
//!   i32 (`Σ ±q = 2·Σ_{bits set} q − Σq`), the row scale applied once
//!   per output value. Integer addition is exact at any association,
//!   so *every* vector lane is bit-identical to the scalar i32 oracle
//!   (`row_pos_i8_scalar`). The AVX2 body is a maddubs-style i8 dot:
//!   expand 32 sign bits to a byte select mask, `maddubs(1, q&mask)`
//!   into i16 pairs (|q| ≤ 127 so pairs can't saturate), widen with
//!   `madd` into 8 i32 accumulators.
//!
//! The lane is chosen per [`crate::util::simd::Level`], captured at
//! engine construction through [`EngineCtx`]. A true XNOR+POPCNT path
//! ([`xnor_popcnt_gemm`]) is provided for binary activations (App. F /
//! BNN-style fully-binary inference); popcount is integer math, so
//! that one stays bit-identical on every lane too.

use super::EngineCtx;
use crate::bitops::{hamming_words_padded, BitMatrix};
use crate::quant::binarize::BinaryLayer;
use crate::tensor::Matrix;
use crate::util::parallel;
use crate::util::simd::Level;

/// Σ x over the set bits of `w`, offset by `base` — the scalar set-bit
/// walk, also used for the vector lanes' final partial word.
#[inline(always)]
fn sum_where_set(mut w: u64, xrow: &[f32], base: usize) -> f32 {
    let mut s = 0f32;
    while w != 0 {
        let t = w.trailing_zeros() as usize;
        s += xrow[base + t];
        w &= w - 1;
    }
    s
}

/// Integer twin of [`sum_where_set`]: Σ q over set bits, exact i32.
#[inline(always)]
fn sum_where_set_i8(mut w: u64, qrow: &[i8], base: usize) -> i32 {
    let mut s = 0i32;
    while w != 0 {
        let t = w.trailing_zeros() as usize;
        s += qrow[base + t] as i32;
        w &= w - 1;
    }
    s
}

/// Scalar oracle for one weight row: single sequential accumulator in
/// word-then-bit order — exactly the pre-SIMD loop, so
/// `PALLAS_SIMD=scalar` stays bit-identical to historical outputs.
fn row_pos_scalar(brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
    let mut pos = 0f32;
    for (wi, &bw) in brow.iter().enumerate() {
        let mut w = match gmask {
            Some(m) => bw & m[wi],
            None => bw,
        };
        let base = wi * 64;
        while w != 0 {
            let t = w.trailing_zeros() as usize;
            pos += xrow[base + t];
            w &= w - 1;
        }
    }
    pos
}

/// Scalar i32 oracle for the W1A8 lane: same word-then-bit walk as
/// [`row_pos_scalar`], accumulating int8 codes exactly. Every vector
/// lane must reproduce this bit-for-bit (integer adds are exact, so
/// reassociation is free).
fn row_pos_i8_scalar(brow: &[u64], gmask: Option<&[u64]>, qrow: &[i8]) -> i32 {
    let mut pos = 0i32;
    for (wi, &bw) in brow.iter().enumerate() {
        let mut w = match gmask {
            Some(m) => bw & m[wi],
            None => bw,
        };
        let base = wi * 64;
        while w != 0 {
            let t = w.trailing_zeros() as usize;
            pos += qrow[base + t] as i32;
            w &= w - 1;
        }
    }
    pos
}

/// Branchless 8-lane masked accumulate body shared by the non-x86
/// vector wrappers: select via sign-bit AND masks (never `0 * inf`),
/// 8 independent sub-accumulators reduced pairwise at the end.
#[cfg(target_arch = "aarch64")]
#[inline(always)]
fn row_pos_lanes_generic(brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
    let full = xrow.len() / 64;
    let mut acc = [0f32; 8];
    for wi in 0..full {
        let w = match gmask {
            Some(m) => brow[wi] & m[wi],
            None => brow[wi],
        };
        if w == 0 {
            continue;
        }
        let xw = &xrow[wi * 64..wi * 64 + 64];
        for byte in 0..8 {
            let b = (w >> (byte * 8)) & 0xff;
            if b == 0 {
                continue;
            }
            let xs = &xw[byte * 8..byte * 8 + 8];
            for (l, a) in acc.iter_mut().enumerate() {
                let keep = 0u32.wrapping_sub(((b >> l) & 1) as u32);
                *a += f32::from_bits(xs[l].to_bits() & keep);
            }
        }
    }
    let mut pos = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    if full < brow.len() {
        let w = match gmask {
            Some(m) => brow[full] & m[full],
            None => brow[full],
        };
        pos += sum_where_set(w, xrow, full * 64);
    }
    pos
}

/// Branchless integer body for the non-x86 vector wrappers (NEON
/// recompiles it so LLVM emits widening-add sequences): 8 independent
/// i32 sub-accumulators, sign-bit AND masks. Exact, therefore
/// bit-identical to [`row_pos_i8_scalar`] regardless of lane count.
#[cfg(target_arch = "aarch64")]
#[inline(always)]
fn row_pos_i8_lanes_generic(brow: &[u64], gmask: Option<&[u64]>, qrow: &[i8]) -> i32 {
    let full = qrow.len() / 64;
    let mut acc = [0i32; 8];
    for wi in 0..full {
        let w = match gmask {
            Some(m) => brow[wi] & m[wi],
            None => brow[wi],
        };
        if w == 0 {
            continue;
        }
        let qw = &qrow[wi * 64..wi * 64 + 64];
        for byte in 0..8 {
            let b = (w >> (byte * 8)) & 0xff;
            if b == 0 {
                continue;
            }
            let qs = &qw[byte * 8..byte * 8 + 8];
            for (l, a) in acc.iter_mut().enumerate() {
                let keep = 0i32.wrapping_sub(((b >> l) & 1) as i32);
                *a += (qs[l] as i32) & keep;
            }
        }
    }
    let mut pos = acc.iter().sum::<i32>();
    if full < brow.len() {
        let w = match gmask {
            Some(m) => brow[full] & m[full],
            None => brow[full],
        };
        pos += sum_where_set_i8(w, qrow, full * 64);
    }
    pos
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    #[inline(always)]
    unsafe fn hsum(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps(v, 1);
        let lo = _mm256_castps256_ps128(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    #[inline(always)]
    unsafe fn hsum_i32(v: __m256i) -> i32 {
        let hi = _mm256_extracti128_si256(v, 1);
        let lo = _mm256_castsi256_si128(v);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b01));
        _mm_cvtsi128_si32(s)
    }

    /// Expand 32 sign bits into a 32-byte select mask (0xFF where the
    /// bit is set). `set1_epi32` repeats the word in both 128-bit
    /// halves, so the per-half `shuffle_epi8` spread stays in-lane.
    #[inline(always)]
    unsafe fn mask32(w32: u32) -> __m256i {
        let spread = _mm256_setr_epi8(
            0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1, 1, 1, 2, 2, 2, 2, 2, 2, 2, 2, 3, 3, 3, 3,
            3, 3, 3, 3,
        );
        let bits = _mm256_setr_epi8(
            1, 2, 4, 8, 16, 32, 64, -128, 1, 2, 4, 8, 16, 32, 64, -128, 1, 2, 4, 8, 16, 32, 64,
            -128, 1, 2, 4, 8, 16, 32, 64, -128,
        );
        let v = _mm256_shuffle_epi8(_mm256_set1_epi32(w32 as i32), spread);
        _mm256_cmpeq_epi8(_mm256_and_si256(v, bits), bits)
    }

    /// Masked sign-accumulate for one weight row: each byte of the
    /// (group-masked) sign word is broadcast and compared against the
    /// per-lane bit positions to build an 8-lane select mask for one
    /// unaligned f32 load — no data-dependent branches in the lane
    /// body. Final partial word falls back to the scalar walk
    /// (padding bits are zero by BitMatrix construction).
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 (guaranteed by
    /// dispatching on [`crate::util::simd::Level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn row_pos(brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
        let full = xrow.len() / 64;
        let bits = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
        let mut acc = _mm256_setzero_ps();
        let p = xrow.as_ptr();
        for wi in 0..full {
            let w = match gmask {
                Some(m) => brow[wi] & m[wi],
                None => brow[wi],
            };
            if w == 0 {
                continue;
            }
            for byte in 0..8 {
                let b = ((w >> (byte * 8)) & 0xff) as i32;
                if b == 0 {
                    continue;
                }
                let sel = _mm256_cmpeq_epi32(_mm256_and_si256(_mm256_set1_epi32(b), bits), bits);
                let xv = _mm256_loadu_ps(p.add(wi * 64 + byte * 8));
                acc = _mm256_add_ps(acc, _mm256_and_ps(_mm256_castsi256_ps(sel), xv));
            }
        }
        let mut pos = hsum(acc);
        if full < brow.len() {
            let w = match gmask {
                Some(m) => brow[full] & m[full],
                None => brow[full],
            };
            pos += super::sum_where_set(w, xrow, full * 64);
        }
        pos
    }

    /// W1A8 row contraction, maddubs-style: per 32-bit half-word, mask
    /// 32 int8 codes by the expanded sign bits, `maddubs(1, ·)` into
    /// i16 pairs (each product ≤ 127, pair sum ≤ 254 — saturation is
    /// unreachable), widen with `madd(·, 1)` into 8 i32 accumulators.
    /// Every add is exact, so the result is bit-identical to
    /// [`super::row_pos_i8_scalar`].
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 (guaranteed by
    /// dispatching on [`crate::util::simd::Level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn row_pos_i8(brow: &[u64], gmask: Option<&[u64]>, qrow: &[i8]) -> i32 {
        let full = qrow.len() / 64;
        let ones8 = _mm256_set1_epi8(1);
        let ones16 = _mm256_set1_epi16(1);
        let mut acc = _mm256_setzero_si256();
        let p = qrow.as_ptr();
        for wi in 0..full {
            let w = match gmask {
                Some(m) => brow[wi] & m[wi],
                None => brow[wi],
            };
            if w == 0 {
                continue;
            }
            for half in 0..2usize {
                let h = (w >> (half * 32)) as u32;
                if h == 0 {
                    continue;
                }
                let qv = _mm256_loadu_si256(p.add(wi * 64 + half * 32) as *const __m256i);
                let masked = _mm256_and_si256(mask32(h), qv);
                let pairs = _mm256_maddubs_epi16(ones8, masked);
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(pairs, ones16));
            }
        }
        let mut pos = hsum_i32(acc);
        if full < brow.len() {
            let w = match gmask {
                Some(m) => brow[full] & m[full],
                None => brow[full],
            };
            pos += super::sum_where_set_i8(w, qrow, full * 64);
        }
        pos
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    /// # Safety
    /// Caller must ensure the CPU supports NEON (guaranteed by
    /// dispatching on [`crate::util::simd::Level`]).
    #[target_feature(enable = "neon")]
    pub unsafe fn row_pos(brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
        super::row_pos_lanes_generic(brow, gmask, xrow)
    }

    /// # Safety
    /// Caller must ensure the CPU supports NEON (guaranteed by
    /// dispatching on [`crate::util::simd::Level`]).
    #[target_feature(enable = "neon")]
    pub unsafe fn row_pos_i8(brow: &[u64], gmask: Option<&[u64]>, qrow: &[i8]) -> i32 {
        super::row_pos_i8_lanes_generic(brow, gmask, qrow)
    }
}

/// `pos = Σ x` over columns whose (optionally group-masked) sign bit
/// is set, dispatched on `level`.
#[inline]
fn row_pos(level: Level, brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 | Level::Avx512 => unsafe { x86::row_pos(brow, gmask, xrow) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { arm::row_pos(brow, gmask, xrow) },
        _ => row_pos_scalar(brow, gmask, xrow),
    }
}

/// `pos = Σ q` over columns whose (optionally group-masked) sign bit
/// is set, dispatched on `level`. Exact at every level.
#[inline]
fn row_pos_i8(level: Level, brow: &[u64], gmask: Option<&[u64]>, qrow: &[i8]) -> i32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 | Level::Avx512 => unsafe { x86::row_pos_i8(brow, gmask, qrow) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { arm::row_pos_i8(brow, gmask, qrow) },
        _ => row_pos_i8_scalar(brow, gmask, qrow),
    }
}

/// Prepared sign-GEMM engine for one binarized layer (W1A16 f32 lane
/// and W1A8 integer lane).
#[derive(Debug, Clone)]
pub struct BinaryGemmEngine {
    pub out: usize,
    pub cols: usize,
    pub n_groups: usize,
    b: BitMatrix,
    alpha: Vec<f32>,
    mu: Vec<f32>,
    /// Per-group column bitmask, one mask row of `words_per_row` words.
    group_masks: Vec<Vec<u64>>,
    /// Dispatch lane captured at construction (never changes mid-serve).
    level: Level,
}

impl BinaryGemmEngine {
    /// Build from a binarized layer — the canonical constructor. The
    /// engine captures the ctx's dispatch lane; `gather_tile` and
    /// `act_quant` do not apply here (per-row int8 rows arrive already
    /// quantized through [`super::Activations::I8`]).
    pub fn with_ctx(layer: &BinaryLayer, ctx: &EngineCtx) -> BinaryGemmEngine {
        let wpr = layer.b.words_per_row;
        let mut group_masks = vec![vec![0u64; wpr]; layer.n_groups];
        for (c, &g) in layer.col_group.iter().enumerate() {
            group_masks[g as usize][c / 64] |= 1u64 << (c % 64);
        }
        BinaryGemmEngine {
            out: layer.rows,
            cols: layer.cols,
            n_groups: layer.n_groups,
            b: layer.b.clone(),
            alpha: layer.alpha.clone(),
            mu: layer.mu.clone(),
            group_masks,
            level: ctx.simd_level,
        }
    }

    #[deprecated(note = "use `BinaryGemmEngine::with_ctx(layer, &EngineCtx::current())`")]
    pub fn new(layer: &BinaryLayer) -> BinaryGemmEngine {
        Self::with_ctx(layer, &EngineCtx::current())
    }

    #[deprecated(
        note = "use `BinaryGemmEngine::with_ctx(layer, &EngineCtx::current().with_level(level))`"
    )]
    pub fn new_with_level(layer: &BinaryLayer, level: Level) -> BinaryGemmEngine {
        Self::with_ctx(layer, &EngineCtx::current().with_level(level))
    }

    /// The dispatch lane this engine was built with.
    pub fn level(&self) -> Level {
        self.level
    }

    /// y = x @ Ŵᵀ without dequantization. x: (m, cols) -> (m, out).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        if self.n_groups == 1 {
            return self.forward_ungrouped(x);
        }
        self.forward_grouped(x)
    }

    /// W1A8 forward from per-row int8 activations: the contraction
    /// runs entirely in i32 and `scales[i]` multiplies once per output
    /// value — `y = s·(alpha·(2·pos − Σq) + mu·Σq)`. `q` is row-major
    /// `(rows, cols)` with one scale per row. Parallel splits mirror
    /// [`Self::forward`]; integer adds are exact, so the result is
    /// bit-identical across thread counts AND dispatch levels.
    pub fn forward_i8(&self, q: &[i8], scales: &[f32], rows: usize, cols: usize) -> Matrix {
        assert_eq!(cols, self.cols);
        assert_eq!(q.len(), rows * cols);
        assert_eq!(scales.len(), rows);
        if self.n_groups == 1 {
            return self.forward_ungrouped_i8(q, scales, rows, cols);
        }
        self.forward_grouped_i8(q, scales, rows, cols)
    }

    /// Fast path (single scale group): `Σ±x = 2·Σ_{set bits}x − Σx`.
    /// Perf §Perf note: a branchless sign-XOR variant
    /// (`acc += f32::from_bits(x ^ flip)`) was tried and measured
    /// ~1.7x SLOWER at the Fig. 5 shape — the per-lane variable shifts
    /// defeat LLVM's vectorizer — so the scalar lane keeps set-bit
    /// iteration and the AVX2 lane uses compare-mask selects instead.
    ///
    /// Thread-parallel over input rows (batch decode / prefill) or,
    /// at m == 1, over output-row chunks; each output value is
    /// computed by the same per-row loop either way (bit-identical
    /// across thread counts at a fixed dispatch level).
    fn forward_ungrouped(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols);
        let m = x.rows;
        let out_n = self.out;
        let mut y = Matrix::zeros(m, out_n);
        let nt = parallel::threads_for(m * out_n * (self.cols / 2).max(1));
        if m == 1 {
            let xrow = x.row(0);
            let xsum: f32 = xrow.iter().sum();
            parallel::par_row_ranges_with(nt, &mut y.data, 1, |r0, chunk| {
                self.outs_ungrouped(xrow, xsum, r0, chunk);
            });
        } else {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let xrow = x.row(i0 + ii);
                    let xsum: f32 = xrow.iter().sum();
                    self.outs_ungrouped(xrow, xsum, 0, yrow);
                }
            });
        }
        y
    }

    /// Integer twin of [`Self::forward_ungrouped`].
    fn forward_ungrouped_i8(&self, q: &[i8], scales: &[f32], rows: usize, cols: usize) -> Matrix {
        let out_n = self.out;
        let mut y = Matrix::zeros(rows, out_n);
        let nt = parallel::threads_for(rows * out_n * (self.cols / 2).max(1));
        if rows == 1 {
            let qrow = &q[..cols];
            let qsum: i32 = qrow.iter().map(|&v| v as i32).sum();
            let s = scales[0];
            parallel::par_row_ranges_with(nt, &mut y.data, 1, |r0, chunk| {
                self.outs_ungrouped_i8(qrow, qsum, s, r0, chunk);
            });
        } else {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let qrow = &q[(i0 + ii) * cols..(i0 + ii + 1) * cols];
                    let qsum: i32 = qrow.iter().map(|&v| v as i32).sum();
                    self.outs_ungrouped_i8(qrow, qsum, scales[i0 + ii], 0, yrow);
                }
            });
        }
        y
    }

    /// Output rows `r0..r0+ys.len()` for one activation row.
    fn outs_ungrouped(&self, xrow: &[f32], xsum: f32, r0: usize, ys: &mut [f32]) {
        for (rr, yv) in ys.iter_mut().enumerate() {
            let r = r0 + rr;
            let pos = row_pos(self.level, self.b.row(r), None, xrow);
            *yv = self.alpha[r] * (2.0 * pos - xsum) + self.mu[r] * xsum;
        }
    }

    /// Integer output rows for one int8 activation row: i32 contraction
    /// first, per-channel weight scales and the row scale applied in
    /// one f32 epilogue per output value.
    fn outs_ungrouped_i8(&self, qrow: &[i8], qsum: i32, s: f32, r0: usize, ys: &mut [f32]) {
        for (rr, yv) in ys.iter_mut().enumerate() {
            let r = r0 + rr;
            let pos = row_pos_i8(self.level, self.b.row(r), None, qrow);
            *yv = s * (self.alpha[r] * (2 * pos - qsum) as f32 + self.mu[r] * qsum as f32);
        }
    }

    /// General path: per-(row, group) scales via masked accumulation.
    /// Parallel split mirrors [`Self::forward_ungrouped`].
    fn forward_grouped(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols);
        let m = x.rows;
        let out_n = self.out;
        let mut y = Matrix::zeros(m, out_n);
        let nt = parallel::threads_for(m * out_n * (self.cols / 2).max(1));
        if m == 1 {
            let xrow = x.row(0);
            let (group_sum, xsum) = self.group_sums(xrow);
            parallel::par_row_ranges_with(nt, &mut y.data, 1, |r0, chunk| {
                self.outs_grouped(xrow, &group_sum, xsum, r0, chunk);
            });
        } else {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let xrow = x.row(i0 + ii);
                    let (group_sum, xsum) = self.group_sums(xrow);
                    self.outs_grouped(xrow, &group_sum, xsum, 0, yrow);
                }
            });
        }
        y
    }

    /// Integer twin of [`Self::forward_grouped`].
    fn forward_grouped_i8(&self, q: &[i8], scales: &[f32], rows: usize, cols: usize) -> Matrix {
        let out_n = self.out;
        let mut y = Matrix::zeros(rows, out_n);
        let nt = parallel::threads_for(rows * out_n * (self.cols / 2).max(1));
        if rows == 1 {
            let qrow = &q[..cols];
            let (group_sum, qsum) = self.group_sums_i8(qrow);
            let s = scales[0];
            parallel::par_row_ranges_with(nt, &mut y.data, 1, |r0, chunk| {
                self.outs_grouped_i8(qrow, &group_sum, qsum, s, r0, chunk);
            });
        } else {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let qrow = &q[(i0 + ii) * cols..(i0 + ii + 1) * cols];
                    let (group_sum, qsum) = self.group_sums_i8(qrow);
                    self.outs_grouped_i8(qrow, &group_sum, qsum, scales[i0 + ii], 0, yrow);
                }
            });
        }
        y
    }

    /// Per-group sums (Σ_{c in g} x_c) and their total for one row.
    /// Runs once per activation row (not per output row), so it stays
    /// on the scalar walk at every dispatch level.
    fn group_sums(&self, xrow: &[f32]) -> (Vec<f32>, f32) {
        let mut group_sum = vec![0f32; self.n_groups];
        let mut xsum = 0f32;
        for (g, mask) in self.group_masks.iter().enumerate() {
            let mut s = 0f32;
            for (wi, &mw) in mask.iter().enumerate() {
                let mut w = mw;
                let base = wi * 64;
                while w != 0 {
                    let t = w.trailing_zeros() as usize;
                    s += xrow[base + t];
                    w &= w - 1;
                }
            }
            group_sum[g] = s;
            xsum += s;
        }
        (group_sum, xsum)
    }

    /// Integer twin of [`Self::group_sums`] (exact i32).
    fn group_sums_i8(&self, qrow: &[i8]) -> (Vec<i32>, i32) {
        let mut group_sum = vec![0i32; self.n_groups];
        let mut qsum = 0i32;
        for (g, mask) in self.group_masks.iter().enumerate() {
            let mut s = 0i32;
            for (wi, &mw) in mask.iter().enumerate() {
                let mut w = mw;
                let base = wi * 64;
                while w != 0 {
                    let t = w.trailing_zeros() as usize;
                    s += qrow[base + t] as i32;
                    w &= w - 1;
                }
            }
            group_sum[g] = s;
            qsum += s;
        }
        (group_sum, qsum)
    }

    /// Grouped output rows `r0..r0+ys.len()` for one activation row.
    fn outs_grouped(&self, xrow: &[f32], group_sum: &[f32], xsum: f32, r0: usize, ys: &mut [f32]) {
        for (rr, yv) in ys.iter_mut().enumerate() {
            let r = r0 + rr;
            let brow = self.b.row(r);
            let mut acc = 0f32;
            for (g, mask) in self.group_masks.iter().enumerate() {
                // pos = Σ x over columns where sign=+1 within group g.
                let pos = row_pos(self.level, brow, Some(mask), xrow);
                acc += self.alpha[r * self.n_groups + g] * (2.0 * pos - group_sum[g]);
            }
            *yv = acc + self.mu[r] * xsum;
        }
    }

    /// Grouped integer output rows: per-group i32 contractions, one
    /// f32 epilogue per output value.
    fn outs_grouped_i8(
        &self,
        qrow: &[i8],
        group_sum: &[i32],
        qsum: i32,
        s: f32,
        r0: usize,
        ys: &mut [f32],
    ) {
        for (rr, yv) in ys.iter_mut().enumerate() {
            let r = r0 + rr;
            let brow = self.b.row(r);
            let mut acc = 0f32;
            for (g, mask) in self.group_masks.iter().enumerate() {
                let pos = row_pos_i8(self.level, brow, Some(mask), qrow);
                acc += self.alpha[r * self.n_groups + g] * (2 * pos - group_sum[g]) as f32;
            }
            *yv = s * (acc + self.mu[r] * qsum as f32);
        }
    }

    /// Actually-resident bytes of the engine's owned buffers: packed
    /// sign matrix, f32 scales (held full-width for the hot loop) and
    /// the per-group column masks. A measurement, not the fp16
    /// shipping convention — see `WeightBackend::storage_bits` for the
    /// accounted number.
    pub fn resident_bytes(&self) -> usize {
        self.b.storage_bytes()
            + (self.alpha.len() + self.mu.len()) * 4
            + self.group_masks.iter().map(|m| m.len() * 8).sum::<usize>()
    }
}

/// Fully-binary GEMM: both activations and weights are packed ±1;
/// `y[i,r] = n − 2·d_H` via XNOR+POPCNT (one instruction pair per 64
/// elements — the paper's Eq. 5 arithmetic). Padding bits are zero by
/// `BitMatrix` construction, so the final partial word needs no mask
/// re-check in the inner loop: one uniform unmasked popcount pass
/// ([`hamming_words_padded`]), bit-identical at every dispatch level.
/// Thread-parallel over activation rows; each output is an independent
/// popcount reduction, so the split cannot change results.
pub fn xnor_popcnt_gemm(x: &BitMatrix, w: &BitMatrix) -> Matrix {
    assert_eq!(x.cols, w.cols);
    debug_assert!(x.padding_clean(), "xnor_popcnt_gemm: dirty padding bits in activations");
    debug_assert!(w.padding_clean(), "xnor_popcnt_gemm: dirty padding bits in weights");
    let out_n = w.rows;
    let mut y = Matrix::zeros(x.rows, out_n);
    let nt = parallel::threads_for(x.rows * out_n * (x.cols / 32).max(1));
    parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
        for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
            let xrow = x.row(i0 + ii);
            for (r, yv) in yrow.iter_mut().enumerate() {
                let d = hamming_words_padded(xrow, w.row(r));
                *yv = (x.cols as i32 - 2 * d as i32) as f32;
            }
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::QuantizedActs;
    use crate::quant::arb::arb_quantize;
    use crate::util::proptest::{assert_close, check};
    use crate::util::rng::Rng;
    use crate::util::simd;

    fn eng_at(layer: &BinaryLayer, level: Level) -> BinaryGemmEngine {
        BinaryGemmEngine::with_ctx(layer, &EngineCtx::current().with_level(level))
    }

    #[test]
    fn matches_dequant_gemm_property() {
        check(
            "xnor engine == dequant GEMM",
            12,
            |r: &mut Rng| {
                let (m, n, o) = (1 + r.below(4), 8 * (1 + r.below(12)), 1 + r.below(24));
                (Matrix::randn(m, n, r), Matrix::randn(o, n, r))
            },
            |(x, w)| {
                let q = BinaryLayer::quantize(w);
                let eng = BinaryGemmEngine::with_ctx(&q, &EngineCtx::current());
                let fast = eng.forward(x);
                let slow = x.matmul_bt(&q.reconstruct());
                assert_close(&fast.data, &slow.data, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn grouped_matches_dequant() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(12, 96, &mut rng);
        let groups: Vec<u16> = (0..96).map(|c| (c / 32) as u16).collect();
        let q = arb_quantize(&w, &groups, 3, 6);
        let eng = BinaryGemmEngine::with_ctx(&q, &EngineCtx::current());
        let x = Matrix::randn(4, 96, &mut rng);
        let fast = eng.forward(&x);
        let slow = x.matmul_bt(&q.reconstruct());
        assert_close(&fast.data, &slow.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn xnor_popcnt_matches_fp_property() {
        check(
            "xnor popcnt == fp gemm",
            12,
            |r: &mut Rng| {
                let (m, n, o) = (1 + r.below(4), 1 + r.below(200), 1 + r.below(16));
                let xs: Vec<f32> = (0..m * n).map(|_| r.sign()).collect();
                let ws: Vec<f32> = (0..o * n).map(|_| r.sign()).collect();
                (m, n, o, xs, ws)
            },
            |(m, n, o, xs, ws)| {
                let xb = BitMatrix::from_signs(*m, *n, xs);
                let wb = BitMatrix::from_signs(*o, *n, ws);
                let fast = xnor_popcnt_gemm(&xb, &wb);
                let xm = Matrix::from_vec(*m, *n, xs.clone());
                let wm = Matrix::from_vec(*o, *n, ws.clone());
                assert_close(&fast.data, &xm.matmul_bt(&wm).data, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn batched_forward_bitwise_matches_per_row() {
        // Crossing the parallel threshold must not change a single bit
        // vs running each activation row alone (same engine, so the
        // same dispatch lane on both sides).
        let mut rng = Rng::new(8);
        let w = Matrix::randn(96, 256, &mut rng);
        let q = BinaryLayer::quantize(&w);
        let eng = BinaryGemmEngine::with_ctx(&q, &EngineCtx::current());
        let x = Matrix::randn(8, 256, &mut rng);
        let y = eng.forward(&x);
        for i in 0..x.rows {
            let xi = Matrix::from_vec(1, 256, x.row(i).to_vec());
            let yi = eng.forward(&xi);
            assert_eq!(y.row(i), yi.row(0), "row {i}");
        }
    }

    #[test]
    fn vector_lanes_close_to_scalar_engine() {
        // Full-precision equivalence across every runnable lane; tight
        // ULP-style bounds live in rust/tests/simd_equivalence.rs.
        let mut rng = Rng::new(21);
        let w = Matrix::randn(24, 193, &mut rng); // cols % 64 == 1
        let q = BinaryLayer::quantize(&w);
        let x = Matrix::randn(3, 193, &mut rng);
        let oracle = eng_at(&q, Level::Scalar).forward(&x);
        for l in simd::supported_levels() {
            let y = eng_at(&q, l).forward(&x);
            assert_close(&y.data, &oracle.data, 1e-4, 1e-4)
                .unwrap_or_else(|e| panic!("{l:?}: {e}"));
        }
    }

    #[test]
    fn i8_lanes_bit_identical_across_levels() {
        // The integer lane's contract is *bit*-identity (not a ULP
        // bound): i32 adds are exact at any association. Awkward width
        // on purpose (193 % 64 == 1 exercises the partial-word tail).
        let mut rng = Rng::new(31);
        let w = Matrix::randn(24, 193, &mut rng);
        let q = BinaryLayer::quantize(&w);
        let x = Matrix::randn(3, 193, &mut rng);
        let qa = QuantizedActs::quantize(&x, 8);
        let oracle = eng_at(&q, Level::Scalar).forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        for l in simd::supported_levels() {
            let y = eng_at(&q, l).forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
            assert_eq!(y.data, oracle.data, "{l:?}");
        }
    }

    #[test]
    fn i8_forward_matches_f32_forward_on_dequantized_rows() {
        // Semantics check: the integer path must equal the f32 path fed
        // the *dequantized* codes, up to f32 epilogue rounding.
        let mut rng = Rng::new(32);
        let w = Matrix::randn(16, 127, &mut rng);
        let q = BinaryLayer::quantize(&w);
        let eng = BinaryGemmEngine::with_ctx(&q, &EngineCtx::current());
        let x = Matrix::randn(4, 127, &mut rng);
        let qa = QuantizedActs::quantize(&x, 8);
        let yi = eng.forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        let yf = eng.forward(&qa.dequantize());
        assert_close(&yi.data, &yf.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn grouped_i8_matches_dequant_reference() {
        // Grouped scales through the integer path, including an empty
        // group's zero contribution.
        let mut rng = Rng::new(33);
        let w = Matrix::randn(12, 96, &mut rng);
        let groups: Vec<u16> = (0..96).map(|c| (c / 32) as u16).collect();
        let q = arb_quantize(&w, &groups, 3, 6);
        let eng = BinaryGemmEngine::with_ctx(&q, &EngineCtx::current());
        let x = Matrix::randn(4, 96, &mut rng);
        let qa = QuantizedActs::quantize(&x, 8);
        let yi = eng.forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        let slow = qa.dequantize().matmul_bt(&q.reconstruct());
        assert_close(&yi.data, &slow.data, 1e-2, 1e-2).unwrap();
    }

    #[test]
    fn i8_batched_forward_bitwise_matches_per_row() {
        // The batch split must not change a bit of the integer path.
        let mut rng = Rng::new(34);
        let w = Matrix::randn(96, 256, &mut rng);
        let q = BinaryLayer::quantize(&w);
        let eng = BinaryGemmEngine::with_ctx(&q, &EngineCtx::current());
        let x = Matrix::randn(8, 256, &mut rng);
        let qa = QuantizedActs::quantize(&x, 8);
        let y = eng.forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        for i in 0..qa.rows {
            let qrow = &qa.q[i * qa.cols..(i + 1) * qa.cols];
            let yi = eng.forward_i8(qrow, &qa.scales[i..i + 1], 1, qa.cols);
            assert_eq!(y.row(i), yi.row(0), "row {i}");
        }
    }

    #[test]
    fn resident_bytes_equal_sum_of_owned_buffers() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(64, 128, &mut rng);
        let eng = BinaryGemmEngine::with_ctx(&BinaryLayer::quantize(&w), &EngineCtx::current());
        // 64 rows x 2 words x 8 bytes + f32 scales + 1 group mask row.
        assert_eq!(eng.resident_bytes(), 64 * 2 * 8 + 2 * 64 * 4 + 2 * 8);
    }
}
