//! W1A16 sign-GEMM engine over bit-packed ±1 weights (paper Fig. 5,
//! 1-bit lane): `y[i,r] = Σ_g alpha[r,g]·Σ_{c∈g} ±x[i,c] + mu[r]·Σx`.
//!
//! No dequantized weight is ever materialized: the ±1 contraction uses
//! the identity `Σ ±x = 2·Σ_{bits set} x − Σ x`. The scalar lane walks
//! the *set* bits of each 64-column word (≈ cols/2 adds) and is the
//! oracle; the AVX2 lane instead turns each sign byte into an 8-lane
//! compare mask and does a masked vector accumulate (8 adds per 8
//! columns, no data-dependent branching), which reassociates the sum —
//! so the vector lanes are ULP-bounded rather than bit-identical
//! against scalar (bound asserted in `rust/tests/simd_equivalence.rs`).
//! The lane is chosen per [`crate::util::simd::Level`], captured at
//! engine construction. A true XNOR+POPCNT path ([`xnor_popcnt_gemm`])
//! is provided for binary activations (App. F / BNN-style fully-binary
//! inference); popcount is integer math, so that one stays
//! bit-identical on every lane.

use crate::bitops::{hamming_words_padded, BitMatrix};
use crate::quant::binarize::BinaryLayer;
use crate::tensor::Matrix;
use crate::util::parallel;
use crate::util::simd::{self, Level};

/// Σ x over the set bits of `w`, offset by `base` — the scalar set-bit
/// walk, also used for the vector lanes' final partial word.
#[inline(always)]
fn sum_where_set(mut w: u64, xrow: &[f32], base: usize) -> f32 {
    let mut s = 0f32;
    while w != 0 {
        let t = w.trailing_zeros() as usize;
        s += xrow[base + t];
        w &= w - 1;
    }
    s
}

/// Scalar oracle for one weight row: single sequential accumulator in
/// word-then-bit order — exactly the pre-SIMD loop, so
/// `PALLAS_SIMD=scalar` stays bit-identical to historical outputs.
fn row_pos_scalar(brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
    let mut pos = 0f32;
    for (wi, &bw) in brow.iter().enumerate() {
        let mut w = match gmask {
            Some(m) => bw & m[wi],
            None => bw,
        };
        let base = wi * 64;
        while w != 0 {
            let t = w.trailing_zeros() as usize;
            pos += xrow[base + t];
            w &= w - 1;
        }
    }
    pos
}

/// Branchless 8-lane masked accumulate body shared by the non-x86
/// vector wrappers: select via sign-bit AND masks (never `0 * inf`),
/// 8 independent sub-accumulators reduced pairwise at the end.
#[cfg(target_arch = "aarch64")]
#[inline(always)]
fn row_pos_lanes_generic(brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
    let full = xrow.len() / 64;
    let mut acc = [0f32; 8];
    for wi in 0..full {
        let w = match gmask {
            Some(m) => brow[wi] & m[wi],
            None => brow[wi],
        };
        if w == 0 {
            continue;
        }
        let xw = &xrow[wi * 64..wi * 64 + 64];
        for byte in 0..8 {
            let b = (w >> (byte * 8)) & 0xff;
            if b == 0 {
                continue;
            }
            let xs = &xw[byte * 8..byte * 8 + 8];
            for (l, a) in acc.iter_mut().enumerate() {
                let keep = 0u32.wrapping_sub(((b >> l) & 1) as u32);
                *a += f32::from_bits(xs[l].to_bits() & keep);
            }
        }
    }
    let mut pos = ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    if full < brow.len() {
        let w = match gmask {
            Some(m) => brow[full] & m[full],
            None => brow[full],
        };
        pos += sum_where_set(w, xrow, full * 64);
    }
    pos
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    #[inline(always)]
    unsafe fn hsum(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps(v, 1);
        let lo = _mm256_castps256_ps128(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// Masked sign-accumulate for one weight row: each byte of the
    /// (group-masked) sign word is broadcast and compared against the
    /// per-lane bit positions to build an 8-lane select mask for one
    /// unaligned f32 load — no data-dependent branches in the lane
    /// body. Final partial word falls back to the scalar walk
    /// (padding bits are zero by BitMatrix construction).
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 (guaranteed by
    /// dispatching on [`crate::util::simd::Level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn row_pos(brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
        let full = xrow.len() / 64;
        let bits = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
        let mut acc = _mm256_setzero_ps();
        let p = xrow.as_ptr();
        for wi in 0..full {
            let w = match gmask {
                Some(m) => brow[wi] & m[wi],
                None => brow[wi],
            };
            if w == 0 {
                continue;
            }
            for byte in 0..8 {
                let b = ((w >> (byte * 8)) & 0xff) as i32;
                if b == 0 {
                    continue;
                }
                let sel = _mm256_cmpeq_epi32(_mm256_and_si256(_mm256_set1_epi32(b), bits), bits);
                let xv = _mm256_loadu_ps(p.add(wi * 64 + byte * 8));
                acc = _mm256_add_ps(acc, _mm256_and_ps(_mm256_castsi256_ps(sel), xv));
            }
        }
        let mut pos = hsum(acc);
        if full < brow.len() {
            let w = match gmask {
                Some(m) => brow[full] & m[full],
                None => brow[full],
            };
            pos += super::sum_where_set(w, xrow, full * 64);
        }
        pos
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    /// # Safety
    /// Caller must ensure the CPU supports NEON (guaranteed by
    /// dispatching on [`crate::util::simd::Level`]).
    #[target_feature(enable = "neon")]
    pub unsafe fn row_pos(brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
        super::row_pos_lanes_generic(brow, gmask, xrow)
    }
}

/// `pos = Σ x` over columns whose (optionally group-masked) sign bit
/// is set, dispatched on `level`.
#[inline]
fn row_pos(level: Level, brow: &[u64], gmask: Option<&[u64]>, xrow: &[f32]) -> f32 {
    match level {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 | Level::Avx512 => unsafe { x86::row_pos(brow, gmask, xrow) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { arm::row_pos(brow, gmask, xrow) },
        _ => row_pos_scalar(brow, gmask, xrow),
    }
}

/// Prepared W1A16 engine for one binarized layer.
#[derive(Debug, Clone)]
pub struct BinaryGemmEngine {
    pub out: usize,
    pub cols: usize,
    pub n_groups: usize,
    b: BitMatrix,
    alpha: Vec<f32>,
    mu: Vec<f32>,
    /// Per-group column bitmask, one mask row of `words_per_row` words.
    group_masks: Vec<Vec<u64>>,
    /// Dispatch lane captured at construction (never changes mid-serve).
    level: Level,
}

impl BinaryGemmEngine {
    pub fn new(layer: &BinaryLayer) -> BinaryGemmEngine {
        Self::new_with_level(layer, simd::active())
    }

    /// Build with an explicit dispatch level (equivalence tests and
    /// benches; production goes through [`Self::new`]).
    pub fn new_with_level(layer: &BinaryLayer, level: Level) -> BinaryGemmEngine {
        let wpr = layer.b.words_per_row;
        let mut group_masks = vec![vec![0u64; wpr]; layer.n_groups];
        for (c, &g) in layer.col_group.iter().enumerate() {
            group_masks[g as usize][c / 64] |= 1u64 << (c % 64);
        }
        BinaryGemmEngine {
            out: layer.rows,
            cols: layer.cols,
            n_groups: layer.n_groups,
            b: layer.b.clone(),
            alpha: layer.alpha.clone(),
            mu: layer.mu.clone(),
            group_masks,
            level,
        }
    }

    /// The dispatch lane this engine was built with.
    pub fn level(&self) -> Level {
        self.level
    }

    /// y = x @ Ŵᵀ without dequantization. x: (m, cols) -> (m, out).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        if self.n_groups == 1 {
            return self.forward_ungrouped(x);
        }
        self.forward_grouped(x)
    }

    /// Fast path (single scale group): `Σ±x = 2·Σ_{set bits}x − Σx`.
    /// Perf §Perf note: a branchless sign-XOR variant
    /// (`acc += f32::from_bits(x ^ flip)`) was tried and measured
    /// ~1.7x SLOWER at the Fig. 5 shape — the per-lane variable shifts
    /// defeat LLVM's vectorizer — so the scalar lane keeps set-bit
    /// iteration and the AVX2 lane uses compare-mask selects instead.
    ///
    /// Thread-parallel over input rows (batch decode / prefill) or,
    /// at m == 1, over output-row chunks; each output value is
    /// computed by the same per-row loop either way (bit-identical
    /// across thread counts at a fixed dispatch level).
    fn forward_ungrouped(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols);
        let m = x.rows;
        let out_n = self.out;
        let mut y = Matrix::zeros(m, out_n);
        let nt = parallel::threads_for(m * out_n * (self.cols / 2).max(1));
        if m == 1 {
            let xrow = x.row(0);
            let xsum: f32 = xrow.iter().sum();
            parallel::par_row_ranges_with(nt, &mut y.data, 1, |r0, chunk| {
                self.outs_ungrouped(xrow, xsum, r0, chunk);
            });
        } else {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let xrow = x.row(i0 + ii);
                    let xsum: f32 = xrow.iter().sum();
                    self.outs_ungrouped(xrow, xsum, 0, yrow);
                }
            });
        }
        y
    }

    /// Output rows `r0..r0+ys.len()` for one activation row.
    fn outs_ungrouped(&self, xrow: &[f32], xsum: f32, r0: usize, ys: &mut [f32]) {
        for (rr, yv) in ys.iter_mut().enumerate() {
            let r = r0 + rr;
            let pos = row_pos(self.level, self.b.row(r), None, xrow);
            *yv = self.alpha[r] * (2.0 * pos - xsum) + self.mu[r] * xsum;
        }
    }

    /// General path: per-(row, group) scales via masked accumulation.
    /// Parallel split mirrors [`Self::forward_ungrouped`].
    fn forward_grouped(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols);
        let m = x.rows;
        let out_n = self.out;
        let mut y = Matrix::zeros(m, out_n);
        let nt = parallel::threads_for(m * out_n * (self.cols / 2).max(1));
        if m == 1 {
            let xrow = x.row(0);
            let (group_sum, xsum) = self.group_sums(xrow);
            parallel::par_row_ranges_with(nt, &mut y.data, 1, |r0, chunk| {
                self.outs_grouped(xrow, &group_sum, xsum, r0, chunk);
            });
        } else {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let xrow = x.row(i0 + ii);
                    let (group_sum, xsum) = self.group_sums(xrow);
                    self.outs_grouped(xrow, &group_sum, xsum, 0, yrow);
                }
            });
        }
        y
    }

    /// Per-group sums (Σ_{c in g} x_c) and their total for one row.
    /// Runs once per activation row (not per output row), so it stays
    /// on the scalar walk at every dispatch level.
    fn group_sums(&self, xrow: &[f32]) -> (Vec<f32>, f32) {
        let mut group_sum = vec![0f32; self.n_groups];
        let mut xsum = 0f32;
        for (g, mask) in self.group_masks.iter().enumerate() {
            let mut s = 0f32;
            for (wi, &mw) in mask.iter().enumerate() {
                let mut w = mw;
                let base = wi * 64;
                while w != 0 {
                    let t = w.trailing_zeros() as usize;
                    s += xrow[base + t];
                    w &= w - 1;
                }
            }
            group_sum[g] = s;
            xsum += s;
        }
        (group_sum, xsum)
    }

    /// Grouped output rows `r0..r0+ys.len()` for one activation row.
    fn outs_grouped(&self, xrow: &[f32], group_sum: &[f32], xsum: f32, r0: usize, ys: &mut [f32]) {
        for (rr, yv) in ys.iter_mut().enumerate() {
            let r = r0 + rr;
            let brow = self.b.row(r);
            let mut acc = 0f32;
            for (g, mask) in self.group_masks.iter().enumerate() {
                // pos = Σ x over columns where sign=+1 within group g.
                let pos = row_pos(self.level, brow, Some(mask), xrow);
                acc += self.alpha[r * self.n_groups + g] * (2.0 * pos - group_sum[g]);
            }
            *yv = acc + self.mu[r] * xsum;
        }
    }

    /// Actually-resident bytes of the engine's owned buffers: packed
    /// sign matrix, f32 scales (held full-width for the hot loop) and
    /// the per-group column masks. A measurement, not the fp16
    /// shipping convention — see `WeightBackend::storage_bits` for the
    /// accounted number.
    pub fn resident_bytes(&self) -> usize {
        self.b.storage_bytes()
            + (self.alpha.len() + self.mu.len()) * 4
            + self.group_masks.iter().map(|m| m.len() * 8).sum::<usize>()
    }
}

/// Fully-binary GEMM: both activations and weights are packed ±1;
/// `y[i,r] = n − 2·d_H` via XNOR+POPCNT (one instruction pair per 64
/// elements — the paper's Eq. 5 arithmetic). Padding bits are zero by
/// `BitMatrix` construction, so the final partial word needs no mask
/// re-check in the inner loop: one uniform unmasked popcount pass
/// ([`hamming_words_padded`]), bit-identical at every dispatch level.
/// Thread-parallel over activation rows; each output is an independent
/// popcount reduction, so the split cannot change results.
pub fn xnor_popcnt_gemm(x: &BitMatrix, w: &BitMatrix) -> Matrix {
    assert_eq!(x.cols, w.cols);
    debug_assert!(x.padding_clean(), "xnor_popcnt_gemm: dirty padding bits in activations");
    debug_assert!(w.padding_clean(), "xnor_popcnt_gemm: dirty padding bits in weights");
    let out_n = w.rows;
    let mut y = Matrix::zeros(x.rows, out_n);
    let nt = parallel::threads_for(x.rows * out_n * (x.cols / 32).max(1));
    parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
        for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
            let xrow = x.row(i0 + ii);
            for (r, yv) in yrow.iter_mut().enumerate() {
                let d = hamming_words_padded(xrow, w.row(r));
                *yv = (x.cols as i32 - 2 * d as i32) as f32;
            }
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::arb::arb_quantize;
    use crate::util::proptest::{assert_close, check};
    use crate::util::rng::Rng;

    #[test]
    fn matches_dequant_gemm_property() {
        check(
            "xnor engine == dequant GEMM",
            12,
            |r: &mut Rng| {
                let (m, n, o) = (1 + r.below(4), 8 * (1 + r.below(12)), 1 + r.below(24));
                (Matrix::randn(m, n, r), Matrix::randn(o, n, r))
            },
            |(x, w)| {
                let q = BinaryLayer::quantize(w);
                let eng = BinaryGemmEngine::new(&q);
                let fast = eng.forward(x);
                let slow = x.matmul_bt(&q.reconstruct());
                assert_close(&fast.data, &slow.data, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn grouped_matches_dequant() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(12, 96, &mut rng);
        let groups: Vec<u16> = (0..96).map(|c| (c / 32) as u16).collect();
        let q = arb_quantize(&w, &groups, 3, 6);
        let eng = BinaryGemmEngine::new(&q);
        let x = Matrix::randn(4, 96, &mut rng);
        let fast = eng.forward(&x);
        let slow = x.matmul_bt(&q.reconstruct());
        assert_close(&fast.data, &slow.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn xnor_popcnt_matches_fp_property() {
        check(
            "xnor popcnt == fp gemm",
            12,
            |r: &mut Rng| {
                let (m, n, o) = (1 + r.below(4), 1 + r.below(200), 1 + r.below(16));
                let xs: Vec<f32> = (0..m * n).map(|_| r.sign()).collect();
                let ws: Vec<f32> = (0..o * n).map(|_| r.sign()).collect();
                (m, n, o, xs, ws)
            },
            |(m, n, o, xs, ws)| {
                let xb = BitMatrix::from_signs(*m, *n, xs);
                let wb = BitMatrix::from_signs(*o, *n, ws);
                let fast = xnor_popcnt_gemm(&xb, &wb);
                let xm = Matrix::from_vec(*m, *n, xs.clone());
                let wm = Matrix::from_vec(*o, *n, ws.clone());
                assert_close(&fast.data, &xm.matmul_bt(&wm).data, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn batched_forward_bitwise_matches_per_row() {
        // Crossing the parallel threshold must not change a single bit
        // vs running each activation row alone (same engine, so the
        // same dispatch lane on both sides).
        let mut rng = Rng::new(8);
        let w = Matrix::randn(96, 256, &mut rng);
        let q = BinaryLayer::quantize(&w);
        let eng = BinaryGemmEngine::new(&q);
        let x = Matrix::randn(8, 256, &mut rng);
        let y = eng.forward(&x);
        for i in 0..x.rows {
            let xi = Matrix::from_vec(1, 256, x.row(i).to_vec());
            let yi = eng.forward(&xi);
            assert_eq!(y.row(i), yi.row(0), "row {i}");
        }
    }

    #[test]
    fn vector_lanes_close_to_scalar_engine() {
        // Full-precision equivalence across every runnable lane; tight
        // ULP-style bounds live in rust/tests/simd_equivalence.rs.
        let mut rng = Rng::new(21);
        let w = Matrix::randn(24, 193, &mut rng); // cols % 64 == 1
        let q = BinaryLayer::quantize(&w);
        let x = Matrix::randn(3, 193, &mut rng);
        let oracle = BinaryGemmEngine::new_with_level(&q, Level::Scalar).forward(&x);
        for l in simd::supported_levels() {
            let y = BinaryGemmEngine::new_with_level(&q, l).forward(&x);
            assert_close(&y.data, &oracle.data, 1e-4, 1e-4)
                .unwrap_or_else(|e| panic!("{l:?}: {e}"));
        }
    }

    #[test]
    fn resident_bytes_equal_sum_of_owned_buffers() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(64, 128, &mut rng);
        let eng = BinaryGemmEngine::new(&BinaryLayer::quantize(&w));
        // 64 rows x 2 words x 8 bytes + f32 scales + 1 group mask row.
        assert_eq!(eng.resident_bytes(), 64 * 2 * 8 + 2 * 64 * 4 + 2 * 8);
    }
}
