//! W1A16 sign-GEMM engine over bit-packed ±1 weights (paper Fig. 5,
//! 1-bit lane): `y[i,r] = Σ_g alpha[r,g]·Σ_{c∈g} ±x[i,c] + mu[r]·Σx`.
//!
//! No dequantized weight is ever materialized: the ±1 contraction uses
//! the identity `Σ ±x = 2·Σ_{bits set} x − Σ x`, so each 64-column word
//! costs one mask + one bit-iteration over the *set* bits (≈ cols/2
//! adds). A true XNOR+POPCNT path ([`xnor_popcnt_gemm`]) is provided
//! for binary activations (App. F / BNN-style fully-binary inference).

use crate::bitops::{hamming_words, BitMatrix};
use crate::quant::binarize::BinaryLayer;
use crate::tensor::Matrix;
use crate::util::parallel;

/// Prepared W1A16 engine for one binarized layer.
#[derive(Debug, Clone)]
pub struct BinaryGemmEngine {
    pub out: usize,
    pub cols: usize,
    pub n_groups: usize,
    b: BitMatrix,
    alpha: Vec<f32>,
    mu: Vec<f32>,
    /// Per-group column bitmask, one mask row of `words_per_row` words.
    group_masks: Vec<Vec<u64>>,
}

impl BinaryGemmEngine {
    pub fn new(layer: &BinaryLayer) -> BinaryGemmEngine {
        let wpr = layer.b.words_per_row;
        let mut group_masks = vec![vec![0u64; wpr]; layer.n_groups];
        for (c, &g) in layer.col_group.iter().enumerate() {
            group_masks[g as usize][c / 64] |= 1u64 << (c % 64);
        }
        BinaryGemmEngine {
            out: layer.rows,
            cols: layer.cols,
            n_groups: layer.n_groups,
            b: layer.b.clone(),
            alpha: layer.alpha.clone(),
            mu: layer.mu.clone(),
            group_masks,
        }
    }

    /// y = x @ Ŵᵀ without dequantization. x: (m, cols) -> (m, out).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        if self.n_groups == 1 {
            return self.forward_ungrouped(x);
        }
        self.forward_grouped(x)
    }

    /// Fast path (single scale group): `Σ±x = 2·Σ_{set bits}x − Σx`,
    /// iterating only the SET bits of each weight word (≈cols/2 adds).
    /// Perf §Perf note: a branchless sign-XOR variant
    /// (`acc += f32::from_bits(x ^ flip)`) was tried and measured
    /// ~1.7x SLOWER at the Fig. 5 shape — the per-lane variable shifts
    /// defeat LLVM's vectorizer — so set-bit iteration stays.
    ///
    /// Thread-parallel over input rows (batch decode / prefill) or,
    /// at m == 1, over output-row chunks; each output value is
    /// computed by the same scalar loop either way (bit-identical).
    fn forward_ungrouped(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols);
        let m = x.rows;
        let out_n = self.out;
        let mut y = Matrix::zeros(m, out_n);
        let nt = parallel::threads_for(m * out_n * (self.cols / 2).max(1));
        if m == 1 {
            let xrow = x.row(0);
            let xsum: f32 = xrow.iter().sum();
            parallel::par_row_ranges_with(nt, &mut y.data, 1, |r0, chunk| {
                self.outs_ungrouped(xrow, xsum, r0, chunk);
            });
        } else {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let xrow = x.row(i0 + ii);
                    let xsum: f32 = xrow.iter().sum();
                    self.outs_ungrouped(xrow, xsum, 0, yrow);
                }
            });
        }
        y
    }

    /// Output rows `r0..r0+ys.len()` for one activation row.
    fn outs_ungrouped(&self, xrow: &[f32], xsum: f32, r0: usize, ys: &mut [f32]) {
        let wpr = self.b.words_per_row;
        for (rr, yv) in ys.iter_mut().enumerate() {
            let r = r0 + rr;
            let brow = self.b.row(r);
            let mut pos = 0f32;
            for wi in 0..wpr {
                let mut w = brow[wi];
                let base = wi * 64;
                while w != 0 {
                    let t = w.trailing_zeros() as usize;
                    pos += xrow[base + t];
                    w &= w - 1;
                }
            }
            *yv = self.alpha[r] * (2.0 * pos - xsum) + self.mu[r] * xsum;
        }
    }

    /// General path: per-(row, group) scales via masked bit iteration.
    /// Parallel split mirrors [`Self::forward_ungrouped`].
    fn forward_grouped(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols);
        let m = x.rows;
        let out_n = self.out;
        let mut y = Matrix::zeros(m, out_n);
        let nt = parallel::threads_for(m * out_n * (self.cols / 2).max(1));
        if m == 1 {
            let xrow = x.row(0);
            let (group_sum, xsum) = self.group_sums(xrow);
            parallel::par_row_ranges_with(nt, &mut y.data, 1, |r0, chunk| {
                self.outs_grouped(xrow, &group_sum, xsum, r0, chunk);
            });
        } else {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let xrow = x.row(i0 + ii);
                    let (group_sum, xsum) = self.group_sums(xrow);
                    self.outs_grouped(xrow, &group_sum, xsum, 0, yrow);
                }
            });
        }
        y
    }

    /// Per-group sums (Σ_{c in g} x_c) and their total for one row.
    fn group_sums(&self, xrow: &[f32]) -> (Vec<f32>, f32) {
        let mut group_sum = vec![0f32; self.n_groups];
        let mut xsum = 0f32;
        for (g, mask) in self.group_masks.iter().enumerate() {
            let mut s = 0f32;
            for (wi, &mw) in mask.iter().enumerate() {
                let mut w = mw;
                let base = wi * 64;
                while w != 0 {
                    let t = w.trailing_zeros() as usize;
                    s += xrow[base + t];
                    w &= w - 1;
                }
            }
            group_sum[g] = s;
            xsum += s;
        }
        (group_sum, xsum)
    }

    /// Grouped output rows `r0..r0+ys.len()` for one activation row.
    fn outs_grouped(&self, xrow: &[f32], group_sum: &[f32], xsum: f32, r0: usize, ys: &mut [f32]) {
        let wpr = self.b.words_per_row;
        for (rr, yv) in ys.iter_mut().enumerate() {
            let r = r0 + rr;
            let brow = self.b.row(r);
            let mut acc = 0f32;
            for g in 0..self.n_groups {
                // pos = Σ x over columns where sign=+1 within group g.
                let mask = &self.group_masks[g];
                let mut pos = 0f32;
                for wi in 0..wpr {
                    let mut w = brow[wi] & mask[wi];
                    let base = wi * 64;
                    while w != 0 {
                        let t = w.trailing_zeros() as usize;
                        pos += xrow[base + t];
                        w &= w - 1;
                    }
                }
                acc += self.alpha[r * self.n_groups + g] * (2.0 * pos - group_sum[g]);
            }
            *yv = acc + self.mu[r] * xsum;
        }
    }

    /// Actually-resident bytes of the engine's owned buffers: packed
    /// sign matrix, f32 scales (held full-width for the hot loop) and
    /// the per-group column masks. A measurement, not the fp16
    /// shipping convention — see `WeightBackend::storage_bits` for the
    /// accounted number.
    pub fn resident_bytes(&self) -> usize {
        self.b.storage_bytes()
            + (self.alpha.len() + self.mu.len()) * 4
            + self.group_masks.iter().map(|m| m.len() * 8).sum::<usize>()
    }
}

/// Fully-binary GEMM: both activations and weights are packed ±1;
/// `y[i,r] = n − 2·d_H` via XNOR+POPCNT (one instruction pair per 64
/// elements — the paper's Eq. 5 arithmetic). Thread-parallel over
/// activation rows; each output is an independent popcount reduction,
/// so the split cannot change results.
pub fn xnor_popcnt_gemm(x: &BitMatrix, w: &BitMatrix) -> Matrix {
    assert_eq!(x.cols, w.cols);
    let mask = x.tail_mask();
    let out_n = w.rows;
    let mut y = Matrix::zeros(x.rows, out_n);
    let nt = parallel::threads_for(x.rows * out_n * (x.cols / 32).max(1));
    parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
        for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
            let xrow = x.row(i0 + ii);
            for (r, yv) in yrow.iter_mut().enumerate() {
                let d = hamming_words(xrow, w.row(r), mask);
                *yv = (x.cols as i32 - 2 * d as i32) as f32;
            }
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::arb::arb_quantize;
    use crate::util::proptest::{assert_close, check};
    use crate::util::rng::Rng;

    #[test]
    fn matches_dequant_gemm_property() {
        check(
            "xnor engine == dequant GEMM",
            12,
            |r: &mut Rng| {
                let (m, n, o) = (1 + r.below(4), 8 * (1 + r.below(12)), 1 + r.below(24));
                (Matrix::randn(m, n, r), Matrix::randn(o, n, r))
            },
            |(x, w)| {
                let q = BinaryLayer::quantize(w);
                let eng = BinaryGemmEngine::new(&q);
                let fast = eng.forward(x);
                let slow = x.matmul_bt(&q.reconstruct());
                assert_close(&fast.data, &slow.data, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn grouped_matches_dequant() {
        let mut rng = Rng::new(3);
        let w = Matrix::randn(12, 96, &mut rng);
        let groups: Vec<u16> = (0..96).map(|c| (c / 32) as u16).collect();
        let q = arb_quantize(&w, &groups, 3, 6);
        let eng = BinaryGemmEngine::new(&q);
        let x = Matrix::randn(4, 96, &mut rng);
        let fast = eng.forward(&x);
        let slow = x.matmul_bt(&q.reconstruct());
        assert_close(&fast.data, &slow.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn xnor_popcnt_matches_fp_property() {
        check(
            "xnor popcnt == fp gemm",
            12,
            |r: &mut Rng| {
                let (m, n, o) = (1 + r.below(4), 1 + r.below(200), 1 + r.below(16));
                let xs: Vec<f32> = (0..m * n).map(|_| r.sign()).collect();
                let ws: Vec<f32> = (0..o * n).map(|_| r.sign()).collect();
                (m, n, o, xs, ws)
            },
            |(m, n, o, xs, ws)| {
                let xb = BitMatrix::from_signs(*m, *n, xs);
                let wb = BitMatrix::from_signs(*o, *n, ws);
                let fast = xnor_popcnt_gemm(&xb, &wb);
                let xm = Matrix::from_vec(*m, *n, xs.clone());
                let wm = Matrix::from_vec(*o, *n, ws.clone());
                assert_close(&fast.data, &xm.matmul_bt(&wm).data, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn batched_forward_bitwise_matches_per_row() {
        // Crossing the parallel threshold must not change a single bit
        // vs running each activation row alone.
        let mut rng = Rng::new(8);
        let w = Matrix::randn(96, 256, &mut rng);
        let q = BinaryLayer::quantize(&w);
        let eng = BinaryGemmEngine::new(&q);
        let x = Matrix::randn(8, 256, &mut rng);
        let y = eng.forward(&x);
        for i in 0..x.rows {
            let xi = Matrix::from_vec(1, 256, x.row(i).to_vec());
            let yi = eng.forward(&xi);
            assert_eq!(y.row(i), yi.row(0), "row {i}");
        }
    }

    #[test]
    fn resident_bytes_equal_sum_of_owned_buffers() {
        let mut rng = Rng::new(4);
        let w = Matrix::randn(64, 128, &mut rng);
        let eng = BinaryGemmEngine::new(&BinaryLayer::quantize(&w));
        // 64 rows x 2 words x 8 bytes + f32 scales + 1 group mask row.
        assert_eq!(eng.resident_bytes(), 64 * 2 * 8 + 2 * 64 * 4 + 2 * 8);
    }
}
