//! **Binary-Codebook LUT-GEMM engine** (paper App. H) — the sub-1-bit
//! serving hot path. No dequantization, no multiplications on the
//! per-output-row path:
//!
//! - Stage-I: per activation block `j` and segment `p` (μ elements),
//!   build the 2^μ signed-sum table with the incremental rule
//!   `LUT[s] = LUT[s − lowbit(s)] + 2·x[bit]` (one add per entry).
//! - Stage-II: `CBLUT[j][k] = Σ_p LUT[j][p][key[k][p]]` using the
//!   offline-packed μ-bit codebook keys.
//! - Gather: `y[r] = Σ_j alpha[r,g(j)]·CBLUT[j][I[r,j]] + mu[r]·Σx`.
//!
//! CBLUT is built once per activation row and reused by every output
//! row — the paper's "amortized over a large tile of output rows".
//! Column groups must be block-aligned (enforced by `try_with_ctx`):
//! the pipeline rounds split-point boundaries to `v`-blocks for
//! deployment.
//!
//! Two activation lanes share this structure: the f32 lane above, and
//! a **W1A8 integer lane** ([`LutGemmEngine::forward_i8`]) whose
//! Stage-I/II tables and gather accumulators are i32 over per-row
//! int8 codes — every add is exact, so the integer lane is
//! bit-identical across dispatch levels, tile widths and thread
//! counts; the row scale and the f16-decoded weight scales multiply
//! once per output value in the f32 epilogue (DESIGN.md §12).

use super::EngineCtx;
use crate::bitops::PackedPlane;
use crate::quant::codebook::CodebookLayer;
use crate::tensor::Matrix;
use crate::util::parallel;
use crate::util::simd::Level;

/// Largest divisor of `v` that is <= 8 (the Stage-I segment width μ).
pub fn pick_mu(v: usize) -> usize {
    for mu in (1..=8).rev() {
        if v % mu == 0 {
            return mu;
        }
    }
    1
}

/// Default output-row tile width of the gather stage: a tile of rows
/// walks the blocks together so each block's `cblut` row stays hot in
/// cache across the whole tile. The per-engine width is tunable
/// (`util::autotune` sweeps it; [`EngineCtx::with_gather_tile`] pins
/// it for tests) — and because each output row's block-accumulation
/// order is fixed at j = 0..nb regardless of tiling, *every* tile
/// width produces bit-identical results.
pub const GATHER_TILE_DEFAULT: usize = 32;

/// Upper bound for the tunable gather tile; the gather's stack
/// buffers are sized to this.
pub const GATHER_TILE_MAX: usize = 64;

/// Per-lane gather accumulate, ungrouped: independent f32 adds per
/// tile lane, j-order fixed by the caller.
#[inline(always)]
fn gather_accum_generic(acc: &mut [f32], cb: &[f32], idx: &[u32]) {
    for (a, &k) in acc.iter_mut().zip(idx) {
        *a += cb[k as usize];
    }
}

/// Per-lane gather accumulate with per-(row, group) scales.
#[inline(always)]
fn gather_accum_grouped_generic(
    acc: &mut [f32],
    cb: &[f32],
    idx: &[u32],
    alpha: &[f32],
    r: usize,
    n_groups: usize,
    g: usize,
) {
    for (rr, (a, &k)) in acc.iter_mut().zip(idx).enumerate() {
        *a += alpha[(r + rr) * n_groups + g] * cb[k as usize];
    }
}

/// Integer gather accumulate (W1A8 lane): exact i32 adds, so every
/// recompile of this body is bit-identical.
#[inline(always)]
fn gather_accum_i32_generic(acc: &mut [i32], cb: &[i32], idx: &[u32]) {
    for (a, &k) in acc.iter_mut().zip(idx) {
        *a += cb[k as usize];
    }
}

// The vector lanes recompile the generic bodies under wider target
// features so LLVM can emit gathered loads / wider mul-add sequences.
// Deliberately NO fma in the enable set: Rust never contracts
// mul-then-add on its own, each tile lane is an independent
// accumulator, and the per-row j-order is unchanged — so these lanes
// stay **bit-identical** to scalar (asserted by
// `packed_gather_bit_identical_to_dense_index_reference` and the
// forced-variant equivalence suite).
#[cfg(target_arch = "x86_64")]
mod lanes {
    /// # Safety
    /// Caller must ensure AVX2 (guaranteed by dispatching on
    /// [`crate::util::simd::Level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn accum(acc: &mut [f32], cb: &[f32], idx: &[u32]) {
        super::gather_accum_generic(acc, cb, idx)
    }

    /// # Safety
    /// Caller must ensure AVX2 (guaranteed by dispatching on
    /// [`crate::util::simd::Level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn accum_grouped(
        acc: &mut [f32],
        cb: &[f32],
        idx: &[u32],
        alpha: &[f32],
        r: usize,
        n_groups: usize,
        g: usize,
    ) {
        super::gather_accum_grouped_generic(acc, cb, idx, alpha, r, n_groups, g)
    }

    /// # Safety
    /// Caller must ensure AVX2 (guaranteed by dispatching on
    /// [`crate::util::simd::Level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn accum_i32(acc: &mut [i32], cb: &[i32], idx: &[u32]) {
        super::gather_accum_i32_generic(acc, cb, idx)
    }
}

#[cfg(target_arch = "aarch64")]
mod lanes {
    /// # Safety
    /// Caller must ensure NEON (guaranteed by dispatching on
    /// [`crate::util::simd::Level`]).
    #[target_feature(enable = "neon")]
    pub unsafe fn accum(acc: &mut [f32], cb: &[f32], idx: &[u32]) {
        super::gather_accum_generic(acc, cb, idx)
    }

    /// # Safety
    /// Caller must ensure NEON (guaranteed by dispatching on
    /// [`crate::util::simd::Level`]).
    #[target_feature(enable = "neon")]
    pub unsafe fn accum_grouped(
        acc: &mut [f32],
        cb: &[f32],
        idx: &[u32],
        alpha: &[f32],
        r: usize,
        n_groups: usize,
        g: usize,
    ) {
        super::gather_accum_grouped_generic(acc, cb, idx, alpha, r, n_groups, g)
    }

    /// # Safety
    /// Caller must ensure NEON (guaranteed by dispatching on
    /// [`crate::util::simd::Level`]).
    #[target_feature(enable = "neon")]
    pub unsafe fn accum_i32(acc: &mut [i32], cb: &[i32], idx: &[u32]) {
        super::gather_accum_i32_generic(acc, cb, idx)
    }
}

/// Prepared LUT-GEMM engine for one codebook-compressed layer.
#[derive(Debug, Clone)]
pub struct LutGemmEngine {
    pub out: usize,
    pub cols: usize,
    pub v: usize,
    pub mu_bits: usize,
    pub segs: usize,
    pub nb: usize,
    pub c: usize,
    /// Centroid indices stored *packed* (`index_bits()` bits each) and
    /// block-major (plane row `j` holds block j's index for every
    /// output row): the gather walks a tile of output rows per block,
    /// decoding one tile into a stack buffer at a time, so the
    /// per-block index reads stay contiguous and the resident plane is
    /// genuinely sub-byte.
    idx_t: PackedPlane,
    /// Codebook keys, c x segs, each a μ-bit pattern.
    keys: Vec<u16>,
    /// Scales decoded from the layer's f16 once at build time (the
    /// hot loop multiplies f32; resident cost is reported honestly by
    /// [`Self::resident_bytes`]).
    alpha: Vec<f32>,
    mu: Vec<f32>,
    /// Per-block group id (block-aligned column groups).
    block_group: Vec<u16>,
    n_groups: usize,
    /// Gather tile width, clamped to `1..=GATHER_TILE_MAX`. Seeded
    /// from the [`EngineCtx`] at construction; bit-identical across
    /// widths (fixed per-row j-order).
    gather_tile: usize,
    /// Dispatch lane captured at construction (never changes mid-serve).
    level: Level,
}

/// Per-thread activation scratch: padded row, Stage-I tables, Stage-II
/// codebook LUT. `xpad`'s tail past `cols` is zeroed once here and
/// never dirtied (rows only overwrite `[..cols]`).
struct Scratch {
    xpad: Vec<f32>,
    lut: Vec<f32>,
    cblut: Vec<f32>,
}

/// Integer twin of [`Scratch`] for the W1A8 lane: int8 padded codes,
/// i32 tables. Bounds: a Stage-II entry is a ±1 contraction of ≤ v
/// int8 codes (|entry| ≤ v·127), a gather accumulator sums ≤ cols·127
/// — both far inside i32.
struct ScratchI8 {
    qpad: Vec<i8>,
    lut: Vec<i32>,
    cblut: Vec<i32>,
}

impl LutGemmEngine {
    /// Build from a codebook layer with an explicit [`EngineCtx`] —
    /// the canonical constructor. Returns `None` when column groups
    /// are not block-aligned (caller falls back to the dequant path).
    /// The ctx's gather tile is clamped to `1..=GATHER_TILE_MAX`.
    pub fn try_with_ctx(layer: &CodebookLayer, ctx: &EngineCtx) -> Option<LutGemmEngine> {
        let v = layer.v;
        let nb = layer.blocks_per_row();
        // Verify block-aligned groups and collect per-block ids.
        let col_group = layer.col_groups();
        let mut block_group = Vec::with_capacity(nb);
        for j in 0..nb {
            let start = j * v;
            let end = ((j + 1) * v).min(layer.cols);
            let g = col_group[start];
            if col_group[start..end].iter().any(|&x| x != g) {
                return None;
            }
            block_group.push(g);
        }
        let mu_bits = pick_mu(v);
        let segs = v / mu_bits;
        let c = layer.codebook.c();
        // Offline key packing: key[k][p] = μ sign bits of centroid k, segment p.
        let mut keys = vec![0u16; c * segs];
        for k in 0..c {
            let w = layer.codebook.words[k];
            for p in 0..segs {
                keys[k * segs + p] = ((w >> (p * mu_bits)) & ((1u64 << mu_bits) - 1)) as u16;
            }
        }
        // Transpose the packed plane to block-major for the tiled
        // gather (k bits per index are preserved — no widening).
        let out = layer.rows;
        let idx_t = layer.idx.transposed();
        debug_assert_eq!((idx_t.rows, idx_t.cols), (nb, out));
        Some(LutGemmEngine {
            out,
            cols: layer.cols,
            v,
            mu_bits,
            segs,
            nb,
            c,
            idx_t,
            keys,
            alpha: layer.alpha_f32(),
            mu: layer.mu_f32(),
            block_group,
            n_groups: layer.n_groups,
            gather_tile: ctx.gather_tile.clamp(1, GATHER_TILE_MAX),
            level: ctx.simd_level,
        })
    }

    #[deprecated(note = "use `LutGemmEngine::try_with_ctx(layer, &EngineCtx::current())`")]
    pub fn try_new(layer: &CodebookLayer) -> Option<LutGemmEngine> {
        Self::try_with_ctx(layer, &EngineCtx::current())
    }

    #[deprecated(
        note = "use `LutGemmEngine::try_with_ctx` with an `EngineCtx` carrying the level and tile"
    )]
    pub fn try_new_with(
        layer: &CodebookLayer,
        level: Level,
        gather_tile: usize,
    ) -> Option<LutGemmEngine> {
        Self::try_with_ctx(
            layer,
            &EngineCtx::current().with_level(level).with_gather_tile(gather_tile),
        )
    }

    /// The dispatch lane this engine was built with.
    pub fn level(&self) -> Level {
        self.level
    }

    fn scratch(&self) -> Scratch {
        Scratch {
            xpad: vec![0f32; self.nb * self.v],
            lut: vec![0f32; self.nb * self.segs * (1usize << self.mu_bits)],
            cblut: vec![0f32; self.nb * self.c],
        }
    }

    fn scratch_i8(&self) -> ScratchI8 {
        ScratchI8 {
            qpad: vec![0i8; self.nb * self.v],
            lut: vec![0i32; self.nb * self.segs * (1usize << self.mu_bits)],
            cblut: vec![0i32; self.nb * self.c],
        }
    }

    /// y = x @ Ŵᵀ via lookup + accumulate. x: (m, cols) -> (m, out).
    ///
    /// Thread-parallel: batched inputs (prefill / fused batch decode)
    /// split *input* rows across workers, each with its own scratch;
    /// a single row (GEMV decode) builds its tables once and splits
    /// the gather's output-row ranges instead. Both splits leave every
    /// output value's accumulation order unchanged (bit-identical to
    /// the serial path).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols);
        let m = x.rows;
        let out_n = self.out;
        let mut y = Matrix::zeros(m, out_n);
        let row_work =
            self.nb * (self.segs << self.mu_bits) + self.nb * self.c + out_n * self.nb;
        let nt = parallel::threads_for(m * row_work);
        if m > 1 && nt > 1 {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                let mut sc = self.scratch();
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let xsum = self.build_tables(x.row(i0 + ii), &mut sc);
                    self.gather(&sc.cblut, xsum, 0, yrow);
                }
            });
        } else {
            let mut sc = self.scratch();
            for i in 0..m {
                let xsum = self.build_tables(x.row(i), &mut sc);
                let cblut = &sc.cblut;
                parallel::par_row_ranges_with(nt, y.row_mut(i), 1, |r0, chunk| {
                    self.gather(cblut, xsum, r0, chunk);
                });
            }
        }
        y
    }

    /// W1A8 forward from per-row int8 activations: i32 Stage-I/II
    /// tables and gather accumulators, the row scale applied once per
    /// output value in the epilogue. `q` is row-major `(rows, cols)`
    /// with one scale per row. Parallel splits mirror
    /// [`Self::forward`]; every integer add is exact, so the result is
    /// bit-identical across dispatch levels, tile widths and thread
    /// counts.
    pub fn forward_i8(&self, q: &[i8], scales: &[f32], rows: usize, cols: usize) -> Matrix {
        assert_eq!(cols, self.cols);
        assert_eq!(q.len(), rows * cols);
        assert_eq!(scales.len(), rows);
        let out_n = self.out;
        let mut y = Matrix::zeros(rows, out_n);
        let row_work =
            self.nb * (self.segs << self.mu_bits) + self.nb * self.c + out_n * self.nb;
        let nt = parallel::threads_for(rows * row_work);
        if rows > 1 && nt > 1 {
            parallel::par_row_ranges_with(nt, &mut y.data, out_n, |i0, chunk| {
                let mut sc = self.scratch_i8();
                for (ii, yrow) in chunk.chunks_mut(out_n).enumerate() {
                    let i = i0 + ii;
                    let qsum = self.build_tables_i8(&q[i * cols..(i + 1) * cols], &mut sc);
                    self.gather_i8(&sc.cblut, qsum, scales[i], 0, yrow);
                }
            });
        } else {
            let mut sc = self.scratch_i8();
            for i in 0..rows {
                let qsum = self.build_tables_i8(&q[i * cols..(i + 1) * cols], &mut sc);
                let cblut = &sc.cblut;
                let s = scales[i];
                parallel::par_row_ranges_with(nt, y.row_mut(i), 1, |r0, chunk| {
                    self.gather_i8(cblut, qsum, s, r0, chunk);
                });
            }
        }
        y
    }

    /// Stage-I + Stage-II for one activation row; returns Σx.
    fn build_tables(&self, xrow: &[f32], sc: &mut Scratch) -> f32 {
        let (v, mu_b, segs, nb, c) = (self.v, self.mu_bits, self.segs, self.nb, self.c);
        let npat = 1usize << mu_b;
        let xsum: f32 = xrow.iter().sum();
        // Tail past `cols` was zeroed at construction and is never
        // written, so only the live prefix needs refreshing.
        sc.xpad[..self.cols].copy_from_slice(xrow);

        // Stage-I: incremental signed-sum tables.
        for j in 0..nb {
            for p in 0..segs {
                let seg = &sc.xpad[j * v + p * mu_b..j * v + (p + 1) * mu_b];
                let t = &mut sc.lut[(j * segs + p) * npat..(j * segs + p + 1) * npat];
                t[0] = -seg.iter().sum::<f32>();
                for s in 1..npat {
                    let low = s & s.wrapping_neg();
                    t[s] = t[s ^ low] + 2.0 * seg[low.trailing_zeros() as usize];
                }
            }
        }

        // Stage-II: codebook LUT (lookup + add per segment). Keys are
        // walked with `chunks_exact` so the per-centroid slice bound
        // checks stay out of the k-loop.
        for j in 0..nb {
            let base_l = j * segs * npat;
            let cb = &mut sc.cblut[j * c..(j + 1) * c];
            match segs {
                1 => {
                    let t0 = &sc.lut[base_l..base_l + npat];
                    for (out, &key) in cb.iter_mut().zip(&self.keys[..c]) {
                        *out = t0[key as usize];
                    }
                }
                2 => {
                    let (t0, t1) = sc.lut[base_l..base_l + 2 * npat].split_at(npat);
                    for (out, kk) in cb.iter_mut().zip(self.keys.chunks_exact(2)) {
                        *out = t0[kk[0] as usize] + t1[kk[1] as usize];
                    }
                }
                _ => {
                    let lut = &sc.lut;
                    for (out, kk) in cb.iter_mut().zip(self.keys.chunks_exact(segs)) {
                        let mut s = 0f32;
                        for (p, &key) in kk.iter().enumerate() {
                            s += lut[base_l + p * npat + key as usize];
                        }
                        *out = s;
                    }
                }
            }
        }
        xsum
    }

    /// Integer Stage-I + Stage-II for one int8 activation row; returns
    /// Σq. Same incremental rule as [`Self::build_tables`], in exact
    /// i32 arithmetic.
    fn build_tables_i8(&self, qrow: &[i8], sc: &mut ScratchI8) -> i32 {
        let (v, mu_b, segs, nb, c) = (self.v, self.mu_bits, self.segs, self.nb, self.c);
        let npat = 1usize << mu_b;
        let qsum: i32 = qrow.iter().map(|&q| q as i32).sum();
        // Tail past `cols` was zeroed at construction and is never
        // written, so only the live prefix needs refreshing.
        sc.qpad[..self.cols].copy_from_slice(qrow);

        for j in 0..nb {
            for p in 0..segs {
                let seg = &sc.qpad[j * v + p * mu_b..j * v + (p + 1) * mu_b];
                let t = &mut sc.lut[(j * segs + p) * npat..(j * segs + p + 1) * npat];
                t[0] = -seg.iter().map(|&q| q as i32).sum::<i32>();
                for s in 1..npat {
                    let low = s & s.wrapping_neg();
                    t[s] = t[s ^ low] + 2 * seg[low.trailing_zeros() as usize] as i32;
                }
            }
        }

        for j in 0..nb {
            let base_l = j * segs * npat;
            let cb = &mut sc.cblut[j * c..(j + 1) * c];
            match segs {
                1 => {
                    let t0 = &sc.lut[base_l..base_l + npat];
                    for (out, &key) in cb.iter_mut().zip(&self.keys[..c]) {
                        *out = t0[key as usize];
                    }
                }
                2 => {
                    let (t0, t1) = sc.lut[base_l..base_l + 2 * npat].split_at(npat);
                    for (out, kk) in cb.iter_mut().zip(self.keys.chunks_exact(2)) {
                        *out = t0[kk[0] as usize] + t1[kk[1] as usize];
                    }
                }
                _ => {
                    let lut = &sc.lut;
                    for (out, kk) in cb.iter_mut().zip(self.keys.chunks_exact(segs)) {
                        let mut s = 0i32;
                        for (p, &key) in kk.iter().enumerate() {
                            s += lut[base_l + p * npat + key as usize];
                        }
                        *out = s;
                    }
                }
            }
        }
        qsum
    }

    /// Ungrouped tile accumulate, dispatched on the engine's lane.
    #[inline]
    fn accum(&self, acc: &mut [f32], cb: &[f32], idx: &[u32]) {
        match self.level {
            #[cfg(target_arch = "x86_64")]
            Level::Avx2 | Level::Avx512 => unsafe { lanes::accum(acc, cb, idx) },
            #[cfg(target_arch = "aarch64")]
            Level::Neon => unsafe { lanes::accum(acc, cb, idx) },
            _ => gather_accum_generic(acc, cb, idx),
        }
    }

    /// Grouped tile accumulate, dispatched on the engine's lane.
    #[inline]
    fn accum_grouped(&self, acc: &mut [f32], cb: &[f32], idx: &[u32], r: usize, g: usize) {
        match self.level {
            #[cfg(target_arch = "x86_64")]
            Level::Avx2 | Level::Avx512 => unsafe {
                lanes::accum_grouped(acc, cb, idx, &self.alpha, r, self.n_groups, g)
            },
            #[cfg(target_arch = "aarch64")]
            Level::Neon => unsafe {
                lanes::accum_grouped(acc, cb, idx, &self.alpha, r, self.n_groups, g)
            },
            _ => gather_accum_grouped_generic(acc, cb, idx, &self.alpha, r, self.n_groups, g),
        }
    }

    /// Integer tile accumulate, dispatched on the engine's lane.
    #[inline]
    fn accum_i32(&self, acc: &mut [i32], cb: &[i32], idx: &[u32]) {
        match self.level {
            #[cfg(target_arch = "x86_64")]
            Level::Avx2 | Level::Avx512 => unsafe { lanes::accum_i32(acc, cb, idx) },
            #[cfg(target_arch = "aarch64")]
            Level::Neon => unsafe { lanes::accum_i32(acc, cb, idx) },
            _ => gather_accum_i32_generic(acc, cb, idx),
        }
    }

    /// Gather-accumulate output rows `r0..r0+ys.len()` from a built
    /// `cblut`, tiled so each block's `cblut` row is reused across a
    /// whole tile of output rows. The block-major packed plane is
    /// decoded `gather_tile` indices at a time into a stack buffer, so
    /// the inner loop is a branch-light table walk over plain u32s.
    /// Per output row the accumulation order stays j = 0..nb, so
    /// tiling (at any width) is bit-identical to the row-at-a-time
    /// loop, and so are the vector lanes (no FMA contraction).
    fn gather(&self, cblut: &[f32], xsum: f32, r0: usize, ys: &mut [f32]) {
        let (nb, c) = (self.nb, self.c);
        let mut ibuf = [0u32; GATHER_TILE_MAX];
        let mut r = r0;
        for tile in ys.chunks_mut(self.gather_tile) {
            let tl = tile.len();
            let mut acc = [0f32; GATHER_TILE_MAX];
            for j in 0..nb {
                let cb = &cblut[j * c..(j + 1) * c];
                self.idx_t.decode_range(j, r, &mut ibuf[..tl]);
                if self.n_groups == 1 {
                    self.accum(&mut acc[..tl], cb, &ibuf[..tl]);
                } else {
                    let g = self.block_group[j] as usize;
                    self.accum_grouped(&mut acc[..tl], cb, &ibuf[..tl], r, g);
                }
            }
            if self.n_groups == 1 {
                for (rr, yv) in tile.iter_mut().enumerate() {
                    *yv = self.alpha[r + rr] * acc[rr] + self.mu[r + rr] * xsum;
                }
            } else {
                for (rr, yv) in tile.iter_mut().enumerate() {
                    *yv = acc[rr] + self.mu[r + rr] * xsum;
                }
            }
            r += tl;
        }
    }

    /// Integer gather: same tiled structure as [`Self::gather`] with
    /// i32 accumulators. Grouped layers keep one i32 accumulator per
    /// (tile lane, group) — the f32 weight scales can't fold into an
    /// integer accumulation, so they move to the epilogue where the
    /// per-group contraction is already exact.
    fn gather_i8(&self, cblut: &[i32], qsum: i32, s: f32, r0: usize, ys: &mut [f32]) {
        let (nb, c) = (self.nb, self.c);
        let mut ibuf = [0u32; GATHER_TILE_MAX];
        let mut r = r0;
        if self.n_groups == 1 {
            for tile in ys.chunks_mut(self.gather_tile) {
                let tl = tile.len();
                let mut acc = [0i32; GATHER_TILE_MAX];
                for j in 0..nb {
                    let cb = &cblut[j * c..(j + 1) * c];
                    self.idx_t.decode_range(j, r, &mut ibuf[..tl]);
                    self.accum_i32(&mut acc[..tl], cb, &ibuf[..tl]);
                }
                for (rr, yv) in tile.iter_mut().enumerate() {
                    *yv = s * (self.alpha[r + rr] * acc[rr] as f32
                        + self.mu[r + rr] * qsum as f32);
                }
                r += tl;
            }
        } else {
            let ng = self.n_groups;
            let mut acc = vec![0i32; GATHER_TILE_MAX * ng];
            for tile in ys.chunks_mut(self.gather_tile) {
                let tl = tile.len();
                acc[..tl * ng].fill(0);
                for j in 0..nb {
                    let cb = &cblut[j * c..(j + 1) * c];
                    self.idx_t.decode_range(j, r, &mut ibuf[..tl]);
                    let g = self.block_group[j] as usize;
                    for (rr, &k) in ibuf[..tl].iter().enumerate() {
                        acc[rr * ng + g] += cb[k as usize];
                    }
                }
                for (rr, yv) in tile.iter_mut().enumerate() {
                    let mut a = 0f32;
                    for (g, &av) in acc[rr * ng..(rr + 1) * ng].iter().enumerate() {
                        a += self.alpha[(r + rr) * ng + g] * av as f32;
                    }
                    *yv = s * (a + self.mu[r + rr] * qsum as f32);
                }
                r += tl;
            }
        }
    }

    /// Actually-resident bytes of the engine's owned buffers: the
    /// packed block-major index plane, the u16 key table, the decoded
    /// f32 scales, and the per-block group ids. This is a measurement,
    /// not the (previously fictional) shipping estimate — pinned equal
    /// to the buffer sizes by a unit test.
    pub fn resident_bytes(&self) -> usize {
        self.idx_t.storage_bytes()
            + self.keys.len() * 2
            + (self.alpha.len() + self.mu.len()) * 4
            + self.block_group.len() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::QuantizedActs;
    use crate::quant::binarize::BinaryLayer;
    use crate::quant::codebook::{collect_vectors, BinaryCodebook, CodebookLayer};
    use crate::util::proptest::{assert_close, check};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn make_codebook_layer(rng: &mut Rng, rows: usize, cols: usize, v: usize, c: usize) -> CodebookLayer {
        let w = Matrix::randn(rows, cols, rng);
        let bl = BinaryLayer::quantize(&w);
        let vectors = collect_vectors(&bl, v);
        let (cb, assign, _) = BinaryCodebook::build(&vectors, v, c, 5);
        CodebookLayer::from_assignments(&bl, Arc::new(cb), assign)
    }

    fn eng(cl: &CodebookLayer) -> Option<LutGemmEngine> {
        LutGemmEngine::try_with_ctx(cl, &EngineCtx::current())
    }

    fn eng_at(cl: &CodebookLayer, level: Level, tile: usize) -> Option<LutGemmEngine> {
        LutGemmEngine::try_with_ctx(
            cl,
            &EngineCtx::current().with_level(level).with_gather_tile(tile),
        )
    }

    #[test]
    fn pick_mu_divides() {
        assert_eq!(pick_mu(16), 8);
        assert_eq!(pick_mu(20), 5);
        assert_eq!(pick_mu(10), 5);
        assert_eq!(pick_mu(12), 6);
        assert_eq!(pick_mu(7), 7);
        assert_eq!(pick_mu(9), 3);
    }

    #[test]
    fn matches_dequant_gemm_property() {
        check(
            "lut engine == dequant GEMM",
            10,
            |r: &mut Rng| {
                let v = *r.choice(&[4usize, 8, 16]);
                let cols = v * (1 + r.below(6));
                let rows = 1 + r.below(24);
                let c = 1 + r.below(40);
                let cl = make_codebook_layer(r, rows, cols, v, c);
                let x = Matrix::randn(1 + r.below(4), cols, r);
                (cl, x)
            },
            |(cl, x)| {
                let eng = eng(cl).ok_or("not block aligned")?;
                let fast = eng.forward(x);
                let slow = x.matmul_bt(&cl.reconstruct());
                assert_close(&fast.data, &slow.data, 1e-3, 1e-3)
            },
        );
    }

    #[test]
    fn ragged_cols_with_padding() {
        // cols not divisible by v: padded blocks must not contribute.
        let mut rng = Rng::new(5);
        let cl = make_codebook_layer(&mut rng, 6, 21, 8, 16); // 21 = 2*8 + 5
        let eng = eng(&cl).unwrap();
        let x = Matrix::randn(3, 21, &mut rng);
        let fast = eng.forward(&x);
        let slow = x.matmul_bt(&cl.reconstruct());
        assert_close(&fast.data, &slow.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn rejects_unaligned_groups() {
        let mut rng = Rng::new(6);
        let base = make_codebook_layer(&mut rng, 4, 16, 8, 8);
        // Rebuild with groups varying inside a block.
        let col_group: Vec<u16> = (0..16).map(|c| (c % 2) as u16).collect();
        let cl = CodebookLayer::new(
            4,
            16,
            base.codebook.clone(),
            &base.idx.to_u32s(),
            &[1.0f32; 8],
            &base.mu_f32(),
            &col_group,
            2,
        );
        assert!(eng(&cl).is_none());
    }

    #[test]
    fn block_aligned_groups_supported() {
        let mut rng = Rng::new(7);
        let w = Matrix::randn(8, 32, &mut rng);
        let groups: Vec<u16> = (0..32).map(|c| (c / 8) as u16).collect(); // v=8 aligned
        let bl = crate::quant::arb::arb_quantize(&w, &groups, 4, 4);
        let vectors = collect_vectors(&bl, 8);
        let (cb, assign, _) = BinaryCodebook::build(&vectors, 8, 16, 5);
        let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
        let eng = eng(&cl).unwrap();
        let x = Matrix::randn(2, 32, &mut rng);
        assert_close(
            &eng.forward(&x).data,
            &x.matmul_bt(&cl.reconstruct()).data,
            1e-3,
            1e-3,
        )
        .unwrap();
    }

    #[test]
    fn stage1_lut_incremental_rule() {
        // Hand-check the incremental table for one segment.
        let mut rng = Rng::new(8);
        let cl = make_codebook_layer(&mut rng, 2, 8, 8, 4);
        let eng = eng(&cl).unwrap();
        assert_eq!(eng.mu_bits, 8);
        assert_eq!(eng.segs, 1);
        // forward already validated; here assert scratch dims derived.
        assert_eq!(eng.nb, 1);
    }

    #[test]
    fn batched_forward_bitwise_matches_per_row() {
        // Batch (parallel input-row split, tiled gather) must agree
        // bit-for-bit with each row run alone through the GEMV path.
        let mut rng = Rng::new(10);
        for c in [16usize, 40] {
            let cl = make_codebook_layer(&mut rng, 70, 64, 16, c);
            let eng = eng(&cl).unwrap();
            let x = Matrix::randn(6, 64, &mut rng);
            let y = eng.forward(&x);
            for i in 0..x.rows {
                let xi = Matrix::from_vec(1, 64, x.row(i).to_vec());
                let yi = eng.forward(&xi);
                assert_eq!(y.row(i), yi.row(0), "c={c} row {i}");
            }
        }
    }

    #[test]
    fn grouped_gather_matches_dequant() {
        // Grouped scales through the tiled gather (out > GATHER_TILE).
        let mut rng = Rng::new(11);
        let w = Matrix::randn(70, 32, &mut rng);
        let groups: Vec<u16> = (0..32).map(|c| (c / 16) as u16).collect(); // v=8 aligned
        let bl = crate::quant::arb::arb_quantize(&w, &groups, 4, 4);
        let vectors = collect_vectors(&bl, 8);
        let (cb, assign, _) = BinaryCodebook::build(&vectors, 8, 12, 5);
        let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
        let eng = eng(&cl).unwrap();
        let x = Matrix::randn(3, 32, &mut rng);
        assert_close(
            &eng.forward(&x).data,
            &x.matmul_bt(&cl.reconstruct()).data,
            1e-3,
            1e-3,
        )
        .unwrap();
    }

    #[test]
    fn every_level_and_tile_bit_identical() {
        // The gather's contract is *bit*-identity across dispatch
        // lanes AND tile widths (fixed per-row j-order, no FMA in the
        // lane bodies) — including out < tile and ragged cols.
        let mut rng = Rng::new(15);
        for (rows, cols, v, c) in [(70usize, 64usize, 16usize, 40usize), (5, 21, 8, 16)] {
            let cl = make_codebook_layer(&mut rng, rows, cols, v, c);
            let x = Matrix::randn(2, cols, &mut rng);
            let oracle = eng_at(&cl, Level::Scalar, GATHER_TILE_DEFAULT).unwrap().forward(&x);
            for l in crate::util::simd::supported_levels() {
                for tile in [1usize, 3, GATHER_TILE_DEFAULT, GATHER_TILE_MAX] {
                    let eng = eng_at(&cl, l, tile).unwrap();
                    assert_eq!(eng.gather_tile, tile);
                    let y = eng.forward(&x);
                    assert_eq!(y.data, oracle.data, "{rows}x{cols} {l:?} tile={tile}");
                }
            }
        }
    }

    #[test]
    fn i8_every_level_and_tile_bit_identical() {
        // The integer lane extends the bit-identity contract to the
        // whole pipeline: tables, gather AND epilogue agree exactly at
        // every dispatch level and tile width (ragged cols included).
        let mut rng = Rng::new(16);
        for (rows, cols, v, c) in [(70usize, 64usize, 16usize, 40usize), (5, 21, 8, 16)] {
            let cl = make_codebook_layer(&mut rng, rows, cols, v, c);
            let x = Matrix::randn(2, cols, &mut rng);
            let qa = QuantizedActs::quantize(&x, 8);
            let oracle = eng_at(&cl, Level::Scalar, GATHER_TILE_DEFAULT)
                .unwrap()
                .forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
            for l in crate::util::simd::supported_levels() {
                for tile in [1usize, 3, GATHER_TILE_MAX] {
                    let y = eng_at(&cl, l, tile)
                        .unwrap()
                        .forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
                    assert_eq!(y.data, oracle.data, "{rows}x{cols} {l:?} tile={tile}");
                }
            }
        }
    }

    #[test]
    fn i8_matches_f32_forward_on_dequantized_rows() {
        // Semantics check: the integer lane equals the f32 lane fed the
        // dequantized codes, up to f32 epilogue rounding.
        let mut rng = Rng::new(17);
        let cl = make_codebook_layer(&mut rng, 40, 96, 16, 32);
        let eng = eng(&cl).unwrap();
        let x = Matrix::randn(3, 96, &mut rng);
        let qa = QuantizedActs::quantize(&x, 8);
        let yi = eng.forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        let yf = eng.forward(&qa.dequantize());
        assert_close(&yi.data, &yf.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn grouped_i8_matches_dequant_reference() {
        // Grouped layers route the integer gather through per-group
        // i32 accumulators; the result must match the dequant GEMM on
        // the dequantized codes.
        let mut rng = Rng::new(18);
        let w = Matrix::randn(70, 32, &mut rng);
        let groups: Vec<u16> = (0..32).map(|c| (c / 16) as u16).collect();
        let bl = crate::quant::arb::arb_quantize(&w, &groups, 4, 4);
        let vectors = collect_vectors(&bl, 8);
        let (cb, assign, _) = BinaryCodebook::build(&vectors, 8, 12, 5);
        let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
        let eng = eng(&cl).unwrap();
        let x = Matrix::randn(3, 32, &mut rng);
        let qa = QuantizedActs::quantize(&x, 8);
        let yi = eng.forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        let slow = qa.dequantize().matmul_bt(&cl.reconstruct());
        assert_close(&yi.data, &slow.data, 1e-2, 1e-2).unwrap();
    }

    #[test]
    fn i8_batched_forward_bitwise_matches_per_row() {
        // The batch split must not change a bit of the integer lane.
        let mut rng = Rng::new(19);
        let cl = make_codebook_layer(&mut rng, 70, 64, 16, 40);
        let eng = eng(&cl).unwrap();
        let x = Matrix::randn(6, 64, &mut rng);
        let qa = QuantizedActs::quantize(&x, 8);
        let y = eng.forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        for i in 0..qa.rows {
            let qrow = &qa.q[i * qa.cols..(i + 1) * qa.cols];
            let yi = eng.forward_i8(qrow, &qa.scales[i..i + 1], 1, qa.cols);
            assert_eq!(y.row(i), yi.row(0), "row {i}");
        }
    }

    #[test]
    fn resident_bytes_equal_sum_of_owned_buffers() {
        // The memory estimate must be a measurement of the buffers the
        // engine actually owns — not a hypothetical packed size.
        let mut rng = Rng::new(9);
        let cl = make_codebook_layer(&mut rng, 70, 256, 16, 256);
        let eng = eng(&cl).unwrap();
        let expect = eng.idx_t.storage_bytes()
            + eng.keys.len() * 2
            + (eng.alpha.len() + eng.mu.len()) * 4
            + eng.block_group.len() * 2;
        assert_eq!(eng.resident_bytes(), expect);
        // And the index plane dominates far below 8 bits/weight.
        let dense_bytes = 70 * 256 * 4;
        assert!(eng.resident_bytes() * 8 < dense_bytes, "{}", eng.resident_bytes());
        // Packed block-major plane: 8-bit codes, nb=16 rows of 70.
        assert_eq!(eng.idx_t.storage_bytes(), 16 * (70 * 8usize).div_ceil(64) * 8);
    }

    #[test]
    fn packed_gather_bit_identical_to_dense_index_reference() {
        // Reference path: same Stage-I/II tables, but the gather walks
        // a dense u32 transposed index plane (the pre-packing layout).
        // The packed-plane gather must agree bit-for-bit.
        let mut rng = Rng::new(14);
        for (rows, cols, v, c) in [(70usize, 64usize, 16usize, 40usize), (33, 48, 8, 200)] {
            let cl = make_codebook_layer(&mut rng, rows, cols, v, c);
            let eng = eng(&cl).unwrap();
            let dense_idx_t: Vec<u32> = {
                let mut t = vec![0u32; rows * eng.nb];
                let idx = cl.idx.to_u32s();
                for r in 0..rows {
                    for j in 0..eng.nb {
                        t[j * rows + r] = idx[r * eng.nb + j];
                    }
                }
                t
            };
            let x = Matrix::randn(1, cols, &mut rng);
            let mut sc = eng.scratch();
            let xsum = eng.build_tables(x.row(0), &mut sc);
            let mut want = vec![0f32; rows];
            let mut r = 0usize;
            for tile in want.chunks_mut(eng.gather_tile) {
                let tl = tile.len();
                let mut acc = [0f32; GATHER_TILE_MAX];
                for j in 0..eng.nb {
                    let cb = &sc.cblut[j * eng.c..(j + 1) * eng.c];
                    let it = &dense_idx_t[j * rows + r..j * rows + r + tl];
                    if eng.n_groups == 1 {
                        for (a, &k) in acc[..tl].iter_mut().zip(it) {
                            *a += cb[k as usize];
                        }
                    } else {
                        let g = eng.block_group[j] as usize;
                        for (rr, (a, &k)) in acc[..tl].iter_mut().zip(it).enumerate() {
                            *a += eng.alpha[(r + rr) * eng.n_groups + g] * cb[k as usize];
                        }
                    }
                }
                for (rr, yv) in tile.iter_mut().enumerate() {
                    *yv = if eng.n_groups == 1 {
                        eng.alpha[r + rr] * acc[rr] + eng.mu[r + rr] * xsum
                    } else {
                        acc[rr] + eng.mu[r + rr] * xsum
                    };
                }
                r += tl;
            }
            let got = eng.forward(&x);
            assert_eq!(got.row(0), &want[..], "{rows}x{cols} v={v} c={c}");
        }
    }
}
