//! Dense fp32 linear engine (baseline lane of Fig. 5, and the exact
//! reference the quantized engines are tested against).

use crate::tensor::Matrix;

/// y = x @ Wᵀ (weights stored (out, in)).
pub fn linear(x: &Matrix, w: &Matrix) -> Matrix {
    x.matmul_bt(w)
}

/// Dequantize-then-GEMM path: reconstructs a dense weight first (the
/// "native PyTorch" lane the paper's LUT kernel is compared against —
/// the dequantization cost is the point).
pub fn dequant_linear(x: &Matrix, reconstruct: impl FnOnce() -> Matrix) -> Matrix {
    let w = reconstruct();
    x.matmul_bt(&w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn linear_matches_matmul() {
        let mut r = Rng::new(1);
        let x = Matrix::randn(3, 8, &mut r);
        let w = Matrix::randn(5, 8, &mut r);
        assert_eq!(linear(&x, &w).data, x.matmul_bt(&w).data);
    }

    #[test]
    fn dequant_path_equals_direct() {
        let mut r = Rng::new(2);
        let x = Matrix::randn(3, 8, &mut r);
        let w = Matrix::randn(5, 8, &mut r);
        let y = dequant_linear(&x, || w.clone());
        assert_eq!(y.data, linear(&x, &w).data);
    }
}
