//! CPU inference engines — the deployed counterparts of the L1 Pallas
//! kernels (same math, validated against each other through the PJRT
//! runtime parity tests):
//!
//! - [`dense`]: fp32 GEMM reference path (the "FP16" baseline lane).
//! - [`xnor`]: sign-GEMM over bit-packed ±1 weights (paper Fig. 5
//!   1-bit lane) with both a W1A16 f32 lane and a true W1A8 integer
//!   lane, plus an XNOR+POPCNT path for binary activations.
//! - [`lutgemm`]: the two-stage Binary-Codebook LUT-GEMM (paper App. H)
//!   — the sub-1-bit serving hot path, no dequantization — likewise
//!   with f32 and int8 table/gather lanes.
//!
//! Engines are surfaced through the [`ComputeEngine`] trait so a
//! [`crate::model::WeightBackend`] can hand its prepared serving path
//! to [`crate::model::Linear`] without the model layer enumerating
//! engine types. The boundary type is [`Activations`]: either f32 rows
//! (the oracle path) or per-row symmetric int8 rows with the scale
//! factored out, so the ±1 contraction can run entirely in i32 and
//! multiply by `scales[i]` once per output value (DESIGN.md §12).

pub mod dense;
pub mod lutgemm;
pub mod xnor;

pub use lutgemm::LutGemmEngine;
pub use xnor::BinaryGemmEngine;

use crate::quant::actquant::ActQuant;
use crate::tensor::Matrix;
use crate::util::simd::{self, Level};

/// Activation rows at the engine boundary.
///
/// `I8` rows carry per-ROW dynamic symmetric quantization:
/// `x[i][c] ≈ scales[i] * q[i*cols + c]`. The row scale factors out of
/// the ±1 contraction, so integer-capable engines accumulate `q` in
/// i32 and apply `scales[i]` (together with the per-channel weight
/// scales) once per output value.
#[derive(Debug, Clone, Copy)]
pub enum Activations<'a> {
    /// Full-precision rows — the oracle path every engine supports.
    F32(&'a Matrix),
    /// Per-row int8 rows (row-major `q`, one scale per row).
    I8 { q: &'a [i8], scales: &'a [f32], rows: usize, cols: usize },
}

impl Activations<'_> {
    pub fn rows(&self) -> usize {
        match self {
            Activations::F32(x) => x.rows,
            Activations::I8 { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Activations::F32(x) => x.cols,
            Activations::I8 { cols, .. } => *cols,
        }
    }

    /// Materialize f32 rows — the fallback used by the trait's default
    /// [`ComputeEngine::forward`] for engines without an integer lane.
    pub fn to_f32(&self) -> Matrix {
        match self {
            Activations::F32(x) => (*x).clone(),
            Activations::I8 { q, scales, rows, cols } => {
                dequantize_rows(q, scales, *rows, *cols)
            }
        }
    }
}

/// `q[i*cols + c] * scales[i]` back to a dense f32 matrix.
pub fn dequantize_rows(q: &[i8], scales: &[f32], rows: usize, cols: usize) -> Matrix {
    assert_eq!(q.len(), rows * cols);
    assert_eq!(scales.len(), rows);
    let mut x = Matrix::zeros(rows, cols);
    for (i, (xrow, qrow)) in x.data.chunks_mut(cols).zip(q.chunks(cols)).enumerate() {
        let s = scales[i];
        for (xv, &qv) in xrow.iter_mut().zip(qrow) {
            *xv = qv as f32 * s;
        }
    }
    x
}

/// Owned per-row symmetric int8 quantization of a batch of activation
/// rows — built once per layer input and shared by every engine fed
/// from the same rows (the quantize-once seam in `transformer.rs`).
#[derive(Debug, Clone)]
pub struct QuantizedActs {
    pub rows: usize,
    pub cols: usize,
    /// Row-major codes, `rows * cols`.
    pub q: Vec<i8>,
    /// One scale per row: `x[i][c] ≈ scales[i] * q[i][c]`.
    pub scales: Vec<f32>,
}

impl QuantizedActs {
    /// Per-row dynamic symmetric quantization at `bits` (2..=8):
    /// `scale = absmax / qmax` (1.0 for an all-zero row), codes
    /// round-to-nearest clamped to `±qmax` so they always fit i8.
    pub fn quantize(x: &Matrix, bits: u32) -> QuantizedActs {
        assert!((2..=8).contains(&bits), "int8 path needs 2..=8 bits, got {bits}");
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let mut q = vec![0i8; x.rows * x.cols];
        let mut scales = vec![1f32; x.rows];
        for (i, (qrow, srow)) in q.chunks_mut(x.cols).zip(scales.iter_mut()).enumerate() {
            let xrow = x.row(i);
            let absmax = xrow.iter().fold(0f32, |m, v| m.max(v.abs()));
            let s = if absmax > 0.0 { absmax / qmax } else { 1.0 };
            *srow = s;
            for (qv, &xv) in qrow.iter_mut().zip(xrow) {
                *qv = (xv / s).round().clamp(-qmax, qmax) as i8;
            }
        }
        QuantizedActs { rows: x.rows, cols: x.cols, q, scales }
    }

    /// Borrow as the engine-boundary enum.
    pub fn as_acts(&self) -> Activations<'_> {
        Activations::I8 { q: &self.q, scales: &self.scales, rows: self.rows, cols: self.cols }
    }

    /// Dequantize back to f32 (the default-impl fallback and tests).
    pub fn dequantize(&self) -> Matrix {
        dequantize_rows(&self.q, &self.scales, self.rows, self.cols)
    }
}

/// Construction-time context for prepared engines — the one builder
/// that replaces the old `new` / `new_with_level` / `try_new_with`
/// constructor sprawl. Passed at `prepare_engine` time so every knob
/// an engine captures (dispatch lane, gather tile, activation
/// quantizer) flows through a single surface.
#[derive(Debug, Clone)]
pub struct EngineCtx {
    /// SIMD dispatch lane, captured at construction (never changes
    /// mid-serve).
    pub simd_level: Level,
    /// LUT gather output-row tile width (clamped by the engine to
    /// `1..=`[`lutgemm::GATHER_TILE_MAX`]).
    pub gather_tile: usize,
    /// The linear's activation quantizer, if any: `bits <= 8` enables
    /// the per-row integer lane on integer-capable engines.
    pub act_quant: Option<ActQuant>,
}

impl EngineCtx {
    /// The process-current context: detected/forced SIMD level, tuned
    /// gather tile, no activation quantizer.
    pub fn current() -> EngineCtx {
        EngineCtx {
            simd_level: simd::active(),
            gather_tile: crate::util::autotune::gather_tile(),
            act_quant: None,
        }
    }

    pub fn with_level(mut self, level: Level) -> EngineCtx {
        self.simd_level = level;
        self
    }

    pub fn with_gather_tile(mut self, tile: usize) -> EngineCtx {
        self.gather_tile = tile;
        self
    }

    pub fn with_act_quant(mut self, aq: Option<ActQuant>) -> EngineCtx {
        self.act_quant = aq;
        self
    }
}

/// A prepared GEMM engine for one weight backend: `y = x @ Ŵᵀ`.
///
/// `forward_f32` is the required oracle path; `forward` is the engine
/// boundary, with a default that dequantizes int8 rows so backends
/// without an integer lane (and pre-existing third-party impls that
/// only know f32) keep working unchanged. Integer-capable engines
/// override `forward` to route `I8` rows to their i32 lanes.
pub trait ComputeEngine: std::fmt::Debug + Send + Sync {
    /// x: (m, in) -> (m, out), f32 activations.
    fn forward_f32(&self, x: &Matrix) -> Matrix;

    /// Engine boundary: f32 rows run the oracle path, int8 rows run
    /// the integer lane when the engine has one (default: dequantize
    /// and fall back to [`Self::forward_f32`]).
    fn forward(&self, x: &Activations<'_>) -> Matrix {
        match x {
            Activations::F32(m) => self.forward_f32(m),
            acts @ Activations::I8 { .. } => self.forward_f32(&acts.to_f32()),
        }
    }

    fn clone_box(&self) -> Box<dyn ComputeEngine>;
}

impl Clone for Box<dyn ComputeEngine> {
    fn clone(&self) -> Box<dyn ComputeEngine> {
        self.clone_box()
    }
}

impl ComputeEngine for BinaryGemmEngine {
    fn forward_f32(&self, x: &Matrix) -> Matrix {
        BinaryGemmEngine::forward(self, x)
    }

    fn forward(&self, x: &Activations<'_>) -> Matrix {
        match x {
            Activations::F32(m) => BinaryGemmEngine::forward(self, m),
            Activations::I8 { q, scales, rows, cols } => {
                self.forward_i8(q, scales, *rows, *cols)
            }
        }
    }

    fn clone_box(&self) -> Box<dyn ComputeEngine> {
        Box::new(self.clone())
    }
}

impl ComputeEngine for LutGemmEngine {
    fn forward_f32(&self, x: &Matrix) -> Matrix {
        LutGemmEngine::forward(self, x)
    }

    fn forward(&self, x: &Activations<'_>) -> Matrix {
        match x {
            Activations::F32(m) => LutGemmEngine::forward(self, m),
            Activations::I8 { q, scales, rows, cols } => {
                self.forward_i8(q, scales, *rows, *cols)
            }
        }
    }

    fn clone_box(&self) -> Box<dyn ComputeEngine> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::assert_close;
    use crate::util::rng::Rng;

    #[test]
    fn per_row_quantize_roundtrip_error_bounded() {
        let mut r = Rng::new(1);
        let x = Matrix::randn(5, 33, &mut r);
        let qa = QuantizedActs::quantize(&x, 8);
        let back = qa.dequantize();
        for i in 0..x.rows {
            // Round-to-nearest on a symmetric grid: error <= scale/2.
            let half = qa.scales[i] * 0.5 + 1e-6;
            for (a, b) in x.row(i).iter().zip(back.row(i)) {
                assert!((a - b).abs() <= half, "{a} vs {b} (half-step {half})");
            }
        }
    }

    #[test]
    fn zero_row_quantizes_to_zero_codes_unit_scale() {
        let x = Matrix::zeros(2, 7);
        let qa = QuantizedActs::quantize(&x, 8);
        assert!(qa.q.iter().all(|&q| q == 0));
        assert!(qa.scales.iter().all(|&s| s == 1.0));
        assert_eq!(qa.dequantize().data, x.data);
    }

    #[test]
    fn codes_stay_within_symmetric_range() {
        let mut r = Rng::new(2);
        for bits in [2u32, 4, 8] {
            let x = Matrix::randn(3, 65, &mut r);
            let qa = QuantizedActs::quantize(&x, bits);
            let qmax = ((1i32 << (bits - 1)) - 1) as i8;
            assert!(qa.q.iter().all(|&q| (-qmax..=qmax).contains(&q)), "bits={bits}");
        }
    }

    #[test]
    fn default_forward_dequantizes_for_f32_only_engines() {
        // An engine that only implements forward_f32 must transparently
        // serve int8 rows through the default dequantize fallback.
        #[derive(Debug, Clone)]
        struct DenseOnly(Matrix);
        impl ComputeEngine for DenseOnly {
            fn forward_f32(&self, x: &Matrix) -> Matrix {
                x.matmul_bt(&self.0)
            }
            fn clone_box(&self) -> Box<dyn ComputeEngine> {
                Box::new(self.clone())
            }
        }
        let mut r = Rng::new(3);
        let w = Matrix::randn(4, 16, &mut r);
        let x = Matrix::randn(2, 16, &mut r);
        let qa = QuantizedActs::quantize(&x, 8);
        let eng = DenseOnly(w.clone());
        let via_acts = eng.forward(&qa.as_acts());
        let via_dequant = qa.dequantize().matmul_bt(&w);
        assert_close(&via_acts.data, &via_dequant.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn engine_ctx_builder_overrides() {
        let ctx = EngineCtx::current()
            .with_level(Level::Scalar)
            .with_gather_tile(7)
            .with_act_quant(Some(ActQuant::identity()));
        assert_eq!(ctx.simd_level, Level::Scalar);
        assert_eq!(ctx.gather_tile, 7);
        assert!(ctx.act_quant.is_some());
    }
}
