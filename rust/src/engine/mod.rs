//! CPU inference engines — the deployed counterparts of the L1 Pallas
//! kernels (same math, validated against each other through the PJRT
//! runtime parity tests):
//!
//! - [`dense`]: fp32 GEMM reference path (the "FP16" baseline lane).
//! - [`xnor`]: W1A16 sign-GEMM over bit-packed ±1 weights (paper Fig. 5
//!   1-bit lane) plus a true XNOR+POPCNT path for binary activations.
//! - [`lutgemm`]: the two-stage Binary-Codebook LUT-GEMM (paper App. H)
//!   — the sub-1-bit serving hot path, no dequantization.

pub mod dense;
pub mod lutgemm;
pub mod xnor;

pub use lutgemm::LutGemmEngine;
pub use xnor::BinaryGemmEngine;
