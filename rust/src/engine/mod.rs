//! CPU inference engines — the deployed counterparts of the L1 Pallas
//! kernels (same math, validated against each other through the PJRT
//! runtime parity tests):
//!
//! - [`dense`]: fp32 GEMM reference path (the "FP16" baseline lane).
//! - [`xnor`]: W1A16 sign-GEMM over bit-packed ±1 weights (paper Fig. 5
//!   1-bit lane) plus a true XNOR+POPCNT path for binary activations.
//! - [`lutgemm`]: the two-stage Binary-Codebook LUT-GEMM (paper App. H)
//!   — the sub-1-bit serving hot path, no dequantization.
//!
//! Engines are surfaced through the [`ComputeEngine`] trait so a
//! [`crate::model::WeightBackend`] can hand its prepared serving path
//! to [`crate::model::Linear`] without the model layer enumerating
//! engine types.

pub mod dense;
pub mod lutgemm;
pub mod xnor;

pub use lutgemm::LutGemmEngine;
pub use xnor::BinaryGemmEngine;

use crate::tensor::Matrix;

/// A prepared GEMM engine for one weight backend: `y = x @ Ŵᵀ`.
pub trait ComputeEngine: std::fmt::Debug + Send + Sync {
    /// x: (m, in) -> (m, out).
    fn forward(&self, x: &Matrix) -> Matrix;

    fn clone_box(&self) -> Box<dyn ComputeEngine>;
}

impl Clone for Box<dyn ComputeEngine> {
    fn clone(&self) -> Box<dyn ComputeEngine> {
        self.clone_box()
    }
}

impl ComputeEngine for BinaryGemmEngine {
    fn forward(&self, x: &Matrix) -> Matrix {
        BinaryGemmEngine::forward(self, x)
    }

    fn clone_box(&self) -> Box<dyn ComputeEngine> {
        Box::new(self.clone())
    }
}

impl ComputeEngine for LutGemmEngine {
    fn forward(&self, x: &Matrix) -> Matrix {
        LutGemmEngine::forward(self, x)
    }

    fn clone_box(&self) -> Box<dyn ComputeEngine> {
        Box::new(self.clone())
    }
}
