//! Minimal offline stand-in for the `anyhow` crate, vendored in-repo
//! because the build image has no crates.io access (see the workspace
//! README / DESIGN.md). Implements the subset this codebase uses:
//!
//! - [`Error`] (message-chain, `Display`/`Debug`)
//! - [`Result<T>`] with `?`-conversion from any `std::error::Error`
//! - [`anyhow!`], [`bail!`], [`ensure!`]
//! - [`Context::context`] / [`Context::with_context`] on `Result` and
//!   `Option`
//!
//! Not implemented (unused here): backtraces, downcasting, source
//! chains as live objects (context is folded into the message).

use std::fmt;

/// Error type: a human-readable message chain.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`,
// exactly like real anyhow — that is what makes this blanket `From`
// coherent alongside `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Fold the source chain into the message.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (on `Result`) or to `None` (on `Option`).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_messages() {
        let e = io_fail().context("loading weights").unwrap_err();
        assert!(e.to_string().starts_with("loading weights: "), "{e}");
        let n: Option<u32> = None;
        let e = n.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "slot 3");
    }

    #[test]
    fn macros_format() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        let e: Error = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }
}
