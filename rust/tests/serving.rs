//! Integration: the coordinator serves a quantized model end-to-end
//! (quantize real artifacts → prepare engines → batched generation),
//! and the TCP front-end streams the same tokens bit for bit over
//! loopback HTTP/SSE. The network tests are hermetic (synthetic
//! model); the artifact tests skip when `make artifacts` hasn't run.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use btc_llm::benchsuite::load_workload;
use btc_llm::coordinator::{NetOptions, NetServer, Server};
use btc_llm::data::{corpus, ByteTokenizer};
use btc_llm::io::weights::ModelConfig;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::fixture::synth_raw_model;

#[test]
#[cfg_attr(debug_assertions, ignore = "pipeline-heavy; run with cargo test --release")]
fn serve_btc_quantized_model() {
    let Ok(w) = load_workload("tinylm_s") else {
        eprintln!("SKIP serve_btc_quantized_model: artifacts missing");
        return;
    };
    let mut cfg = QuantConfig::btc(0.8);
    cfg.transform_outer = 4; // keep the test fast
    let mut qm = quantize_model(&w.raw, &w.corpus, &cfg).unwrap();
    qm.model.prepare_engines();
    let server = Server::start(qm.model, 4, Duration::from_millis(2), 3);
    let tok = ByteTokenizer::default();
    let prompts = corpus::prompts(6, 5);
    let rxs: Vec<_> =
        prompts.iter().map(|p| server.submit(tok.encode(p), 12, 0.0).expect("submit")).collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("generation finished");
        assert!(r.tokens.len() > r.prompt_len, "generated at least one token");
        // Output must decode to ASCII (the model's world).
        let text = tok.decode(&r.tokens);
        assert!(text.is_ascii());
    }
    assert_eq!(
        server.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        6
    );
    server.shutdown();
}

#[test]
fn greedy_generation_continues_grammar() {
    let Ok(w) = load_workload("tinylm_s") else {
        eprintln!("SKIP greedy_generation_continues_grammar: artifacts missing");
        return;
    };
    // FP model, greedy: prompts from the training grammar should
    // complete with in-vocabulary words and end with '.' or newline.
    let qm = quantize_model(&w.raw, &w.corpus, &QuantConfig::fp16()).unwrap();
    let server = Server::start(qm.model, 1, Duration::from_millis(1), 1);
    let tok = ByteTokenizer::default();
    let rx = server.submit(tok.encode("the cat "), 24, 0.0).expect("submit");
    let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    let completion = tok.decode(&r.tokens[r.prompt_len..]);
    assert!(
        completion.chars().all(|c| c.is_ascii_lowercase() || " .()\n".contains(c)),
        "unexpected characters in {completion:?}"
    );
    server.shutdown();
}

// ---------------------------------------------------------------
// Hermetic loopback tests: real OS TCP clients against NetServer,
// on a synthetic model (no trained artifacts needed).
// ---------------------------------------------------------------

fn tiny_net_model() -> btc_llm::model::Transformer {
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 32,
        n_layer: 2,
        n_head: 4,
        n_kv_head: 2,
        d_ff: 64,
        max_seq: 128,
        rope_theta: 10000.0,
    };
    let (raw, corpus) = synth_raw_model(3, cfg);
    let mut qm = quantize_model(&raw, &corpus, &QuantConfig::fp16()).expect("quantize fp16");
    qm.model.prepare_engines();
    qm.model
}

fn ids_body(ids: &[u16]) -> String {
    let inner = ids.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(",");
    format!("[{inner}]")
}

/// One whole-request POST /generate round trip; returns the raw reply
/// (status line + headers + chunked SSE body).
fn post_generate(addr: std::net::SocketAddr, body: &str) -> String {
    let mut conn = TcpStream::connect(addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    write!(
        conn,
        "POST /generate HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .expect("write request");
    let mut reply = String::new();
    conn.read_to_string(&mut reply).expect("read reply");
    reply
}

/// Token ids from the per-token SSE events, in arrival order.
fn sse_tokens(reply: &str) -> Vec<u16> {
    const EV: &str = "data: {\"token\":";
    let mut out = Vec::new();
    let mut rest = reply;
    while let Some(i) = rest.find(EV) {
        let tail = &rest[i + EV.len()..];
        let end = tail.find('}').expect("token event closed");
        out.push(tail[..end].parse::<u16>().expect("token id"));
        rest = &tail[end..];
    }
    out
}

/// Generated ids from the final `done` event's `"tokens":[...]` array.
fn final_tokens(reply: &str) -> Vec<u16> {
    const KEY: &str = "\"tokens\":[";
    let i = reply.find(KEY).expect("final done event present");
    let tail = &reply[i + KEY.len()..];
    let end = tail.find(']').expect("array closed");
    if tail[..end].is_empty() {
        return Vec::new();
    }
    tail[..end].split(',').map(|s| s.parse().expect("token id")).collect()
}

/// The acceptance bar for the wire layer: N OS-thread TCP clients
/// receive token streams bit-identical to in-process
/// `submit_streaming` on the same prompts (greedy determinism is
/// preserved through HTTP parsing, SSE framing and co-scheduling).
#[test]
fn loopback_tcp_streams_are_bit_identical_to_in_process() {
    let model = tiny_net_model();
    let jobs: Vec<(Vec<u16>, usize)> = (0..4usize)
        .map(|k| {
            let plen = 2 + (k * 3) % 9;
            let prompt = (0..plen).map(|j| ((j * 13 + k * 7) % 60) as u16).collect();
            (prompt, 3 + k % 4)
        })
        .collect();

    // In-process references: one request at a time, streamed.
    let solo = Server::start(model.clone(), 1, Duration::from_millis(1), 7);
    let mut want = Vec::new();
    for (p, m) in &jobs {
        let (srx, rrx) = solo.submit_streaming(p.clone(), *m, 0.0).expect("submit");
        let streamed: Vec<u16> = srx.iter().collect();
        let r = rrx.recv_timeout(Duration::from_secs(60)).expect("response");
        assert_eq!(streamed, r.tokens[r.prompt_len..], "stream mirrors response");
        want.push(streamed);
    }
    solo.shutdown();

    // Same prompts, concurrently, over real sockets.
    let server = Arc::new(Server::start(model, 4, Duration::from_millis(1), 7));
    let net = NetServer::bind(server, "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = net.local_addr();
    let clients: Vec<_> = jobs
        .iter()
        .cloned()
        .map(|(p, m)| {
            std::thread::spawn(move || {
                let body =
                    format!("{{\"prompt\":{},\"max_new\":{m},\"stream\":true}}", ids_body(&p));
                post_generate(addr, &body)
            })
        })
        .collect();
    for (client, want) in clients.into_iter().zip(&want) {
        let reply = client.join().expect("client thread");
        assert!(reply.contains("200 OK"), "unexpected reply:\n{reply}");
        assert_eq!(&sse_tokens(&reply), want, "per-token SSE events are bit-identical");
        assert_eq!(&final_tokens(&reply), want, "final event carries the same ids");
    }
    net.shutdown(Duration::from_secs(10));
}

/// Tearing the listener down mid-stream must never leave a connected
/// client blocked: the client always receives a final `done` event
/// (finish `length` if the generation beat the drain deadline,
/// `cancelled` otherwise) and then a clean close.
#[test]
fn tcp_shutdown_mid_stream_unblocks_clients() {
    let model = tiny_net_model();
    let server = Arc::new(Server::start(model, 2, Duration::from_millis(1), 7));
    let watch = server.clone();
    let net = NetServer::bind(server, "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = net.local_addr();
    let client = std::thread::spawn(move || {
        let body = r#"{"prompt":[5,6,7],"max_new":90,"stream":true}"#;
        post_generate(addr, body)
    });
    // Wait until the generation is demonstrably mid-stream, then
    // drain with a short deadline.
    let t0 = std::time::Instant::now();
    while watch.metrics.tokens_generated.load(std::sync::atomic::Ordering::Relaxed) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(30), "generation never started");
        std::thread::sleep(Duration::from_millis(2));
    }
    net.shutdown(Duration::from_millis(50));
    let reply = client.join().expect("client thread returned — not blocked");
    assert!(reply.contains("200 OK"), "unexpected reply:\n{reply}");
    assert!(reply.contains("\"done\":true"), "client got a terminal event:\n{reply}");
}

/// A client that dribbles its request a few bytes at a time (partial
/// reads on the server side) is still parsed and served normally.
#[test]
fn byte_dribbled_request_is_still_served() {
    let model = tiny_net_model();
    let server = Arc::new(Server::start(model, 2, Duration::from_millis(1), 7));
    let net = NetServer::bind(server, "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = net.local_addr();
    let body = r#"{"prompt":[9,8,7],"max_new":4,"stream":true}"#;
    let req = format!(
        "POST /generate HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let mut conn = TcpStream::connect(addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    for chunk in req.as_bytes().chunks(7) {
        conn.write_all(chunk).expect("write chunk");
        conn.flush().unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    let mut reply = String::new();
    conn.read_to_string(&mut reply).expect("read reply");
    assert!(reply.contains("200 OK"), "unexpected reply:\n{reply}");
    assert!(!sse_tokens(&reply).is_empty(), "tokens streamed:\n{reply}");
    assert!(reply.contains("\"done\":true"), "terminal event present:\n{reply}");
    net.shutdown(Duration::from_secs(10));
}

/// Wire-level rejects: malformed requests get clean 4xx + close, and
/// an unknown path 404s — no panics, no hangs.
#[test]
fn malformed_requests_get_clean_errors_over_tcp() {
    let model = tiny_net_model();
    let server = Arc::new(Server::start(model, 2, Duration::from_millis(1), 7));
    let net = NetServer::bind(server, "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = net.local_addr();
    let send = |raw: &str| -> String {
        let mut conn = TcpStream::connect(addr).expect("connect");
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        conn.write_all(raw.as_bytes()).expect("write");
        let mut reply = String::new();
        conn.read_to_string(&mut reply).expect("read");
        reply
    };
    let garbage = send("NOT A REQUEST\r\n\r\n");
    assert!(garbage.contains("400"), "garbage request line:\n{garbage}");
    let bad_json = send(
        "POST /generate HTTP/1.1\r\nContent-Length: 9\r\n\r\nnot json!",
    );
    assert!(bad_json.contains("400"), "unparseable body:\n{bad_json}");
    let missing = send("GET /nope HTTP/1.1\r\n\r\n");
    assert!(missing.contains("404"), "unknown path:\n{missing}");
    let health = send("GET /healthz HTTP/1.1\r\n\r\n");
    assert!(health.contains("200 OK") && health.contains("ok"), "healthz:\n{health}");
    net.shutdown(Duration::from_secs(5));
}
