//! Integration: the coordinator serves a quantized model end-to-end
//! (quantize real artifacts → prepare engines → batched generation).

use std::time::Duration;

use btc_llm::benchsuite::load_workload;
use btc_llm::coordinator::Server;
use btc_llm::data::{corpus, ByteTokenizer};
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};

#[test]
#[cfg_attr(debug_assertions, ignore = "pipeline-heavy; run with cargo test --release")]
fn serve_btc_quantized_model() {
    let Ok(w) = load_workload("tinylm_s") else {
        eprintln!("SKIP serve_btc_quantized_model: artifacts missing");
        return;
    };
    let mut cfg = QuantConfig::btc(0.8);
    cfg.transform_outer = 4; // keep the test fast
    let mut qm = quantize_model(&w.raw, &w.corpus, &cfg).unwrap();
    qm.model.prepare_engines();
    let server = Server::start(qm.model, 4, Duration::from_millis(2), 3);
    let tok = ByteTokenizer::default();
    let prompts = corpus::prompts(6, 5);
    let rxs: Vec<_> =
        prompts.iter().map(|p| server.submit(tok.encode(p), 12, 0.0).expect("submit")).collect();
    for rx in rxs {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("generation finished");
        assert!(r.tokens.len() > r.prompt_len, "generated at least one token");
        // Output must decode to ASCII (the model's world).
        let text = tok.decode(&r.tokens);
        assert!(text.is_ascii());
    }
    assert_eq!(
        server.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        6
    );
    server.shutdown();
}

#[test]
fn greedy_generation_continues_grammar() {
    let Ok(w) = load_workload("tinylm_s") else {
        eprintln!("SKIP greedy_generation_continues_grammar: artifacts missing");
        return;
    };
    // FP model, greedy: prompts from the training grammar should
    // complete with in-vocabulary words and end with '.' or newline.
    let qm = quantize_model(&w.raw, &w.corpus, &QuantConfig::fp16()).unwrap();
    let server = Server::start(qm.model, 1, Duration::from_millis(1), 1);
    let tok = ByteTokenizer::default();
    let rx = server.submit(tok.encode("the cat "), 24, 0.0).expect("submit");
    let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
    let completion = tok.decode(&r.tokens[r.prompt_len..]);
    assert!(
        completion.chars().all(|c| c.is_ascii_lowercase() || " .()\n".contains(c)),
        "unexpected characters in {completion:?}"
    );
    server.shutdown();
}
