//! Chaos: fault-isolated serving under deterministic fault injection
//! (DESIGN.md §10). Every test drives the real server (some over real
//! loopback TCP) with a `util::faultpoint` plan installed and asserts
//! the supervision contract: no hangs, every accepted client gets an
//! answer, zero leaked KV blocks, and survivors of a contained fault
//! stay bit-identical to their solo runs.
//!
//! Fault plans are process-global, so every test here serializes
//! through `faultpoint::scenario` (pass `""` to isolate a test *from*
//! injection). The soak test honors a `PALLAS_FAULTS` env spec when
//! one is set — CI replays it across a seed matrix; a failure
//! reproduces locally from the same spec string.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use btc_llm::coordinator::{
    AdmitPolicy, EvictionKind, FinishReason, NetOptions, NetServer, QosConfig, Server,
    ServerOptions, StopSet, TenantSpec,
};
use btc_llm::io::weights::ModelConfig;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::faultpoint;
use btc_llm::util::fixture::synth_raw_model;

const LONG: Duration = Duration::from_secs(120);

fn tiny_model() -> btc_llm::model::Transformer {
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 32,
        n_layer: 2,
        n_head: 4,
        n_kv_head: 2,
        d_ff: 64,
        max_seq: 128,
        rope_theta: 10000.0,
    };
    let (raw, corpus) = synth_raw_model(3, cfg);
    let mut qm = quantize_model(&raw, &corpus, &QuantConfig::fp16()).expect("quantize fp16");
    qm.model.prepare_engines();
    qm.model
}

fn wait_until(what: &str, mut ok: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !ok() {
        assert!(t0.elapsed() < Duration::from_secs(30), "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Generated ids for `prompt` on an otherwise idle server (the solo
/// reference the determinism assertions compare against).
fn run_solo(server: &Server, prompt: &[u16]) -> Vec<u16> {
    let rx = server.submit_with(prompt.to_vec(), 6, 0.0, StopSet::none(), None).expect("submit");
    let r = rx.recv_timeout(LONG).expect("solo response");
    r.tokens[r.prompt_len..].to_vec()
}

/// One whole-request POST /generate round trip over loopback TCP;
/// returns the raw reply (status line + headers + body).
fn post_generate(addr: SocketAddr, body: &str) -> String {
    raw_roundtrip(
        addr,
        &format!(
            "POST /generate HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        ),
    )
}

fn raw_roundtrip(addr: SocketAddr, req: &str) -> String {
    let mut conn = TcpStream::connect(addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    conn.write_all(req.as_bytes()).expect("write request");
    let mut reply = String::new();
    conn.read_to_string(&mut reply).expect("read reply");
    reply
}

/// Token ids from the per-token SSE events, in arrival order.
fn sse_tokens(reply: &str) -> Vec<u16> {
    const EV: &str = "data: {\"token\":";
    let mut out = Vec::new();
    let mut rest = reply;
    while let Some(i) = rest.find(EV) {
        let tail = &rest[i + EV.len()..];
        let end = tail.find('}').expect("token event closed");
        out.push(tail[..end].parse::<u16>().expect("token id"));
        rest = &tail[end..];
    }
    out
}

/// A prompt that panics in the embedding lookup (id 999 is far out of
/// the synthetic model's 64-token vocabulary) must fail alone:
/// concurrent requests finish bit-identical to their solo runs, the
/// worker survives, and every KV block comes back.
#[test]
fn poisoned_prompt_fails_while_survivors_match_solo() {
    let _iso = faultpoint::scenario("");
    let model = tiny_model();
    let healthy: Vec<Vec<u16>> = vec![vec![5, 6, 7], vec![9, 8], vec![1, 2, 3, 4]];
    let solo = Server::start(model.clone(), 1, Duration::from_millis(1), 7);
    let want: Vec<Vec<u16>> = healthy.iter().map(|p| run_solo(&solo, p)).collect();
    solo.shutdown();

    let server = Server::start(model, 4, Duration::from_millis(20), 7);
    let poisoned = server.submit_with(vec![999], 6, 0.0, StopSet::none(), None).expect("submit");
    let rxs: Vec<_> = healthy
        .iter()
        .map(|p| server.submit_with(p.clone(), 6, 0.0, StopSet::none(), None).expect("submit"))
        .collect();
    let pr = poisoned.recv_timeout(LONG).expect("poisoned request still answered");
    assert_eq!(pr.finish, FinishReason::Failed);
    assert_eq!(pr.tokens.len(), pr.prompt_len, "no tokens survive a prefill poison");
    for (rx, want) in rxs.iter().zip(&want) {
        let r = rx.recv_timeout(LONG).expect("survivor answered");
        assert_eq!(&r.tokens[r.prompt_len..], &want[..], "survivor bit-identical to solo");
    }
    let again = server.submit_with(vec![3, 4], 4, 0.0, StopSet::none(), None).expect("resubmit");
    assert_eq!(again.recv_timeout(LONG).expect("served").finish, FinishReason::Length);
    assert!(server.metrics.panics_caught.load(Relaxed) >= 1);
    assert!(server.metrics.quarantines.load(Relaxed) >= 1);
    wait_until("blocks released", || server.metrics.kv_blocks_in_use.load(Relaxed) == 0);
    server.shutdown();
}

/// Content-keyed decode fault: `decode.token=panic#X` panics any
/// decode round that feeds token X. The fused batch panic is caught,
/// the solo replay pins the culprit (partial output up to the fault),
/// and the co-scheduled request — whose feeds avoid X — replays clean
/// and stays bit-identical to its solo run.
#[test]
fn decode_token_fault_quarantines_only_the_culprit() {
    let model = tiny_model();
    let a_prompt: Vec<u16> = vec![5, 6, 7];
    // Phase 1, fault-free: solo references, X = the first token A
    // feeds back into decode, and a co-request whose feeds avoid X.
    let (x, b_prompt, b_solo) = {
        let _iso = faultpoint::scenario("");
        let solo = Server::start(model.clone(), 1, Duration::from_millis(1), 7);
        let a = run_solo(&solo, &a_prompt);
        assert!(a.len() >= 2, "A must reach its second decode round: {a:?}");
        let x = a[0];
        let mut pick = None;
        for k in 0..32u16 {
            let p = vec![9 + k % 40, (8 + k * 3) % 40];
            let g = run_solo(&solo, &p);
            if !g.contains(&x) && *p.last().unwrap() != x {
                pick = Some((p, g));
                break;
            }
        }
        solo.shutdown();
        let (bp, bg) = pick.expect("some co-request avoids the fault token");
        (x, bp, bg)
    };
    // Phase 2: same prompts, co-scheduled, with the fault armed.
    let _g = faultpoint::scenario(&format!("decode.token=panic#{x}"));
    let server = Server::start(model, 2, Duration::from_millis(20), 7);
    let arx = server.submit_with(a_prompt, 6, 0.0, StopSet::none(), None).expect("submit A");
    let brx = server.submit_with(b_prompt, 6, 0.0, StopSet::none(), None).expect("submit B");
    let a = arx.recv_timeout(LONG).expect("culprit still answered");
    let b = brx.recv_timeout(LONG).expect("survivor answered");
    assert_eq!(a.finish, FinishReason::Failed);
    assert_eq!(&a.tokens[a.prompt_len..], &[x], "partial output up to the fault");
    assert_eq!(b.finish, FinishReason::Length);
    assert_eq!(&b.tokens[b.prompt_len..], &b_solo[..], "survivor bit-identical to solo");
    assert_eq!(server.metrics.quarantines.load(Relaxed), 1, "exactly the culprit");
    assert!(server.metrics.panics_caught.load(Relaxed) >= 2, "fused panic + solo replay");
    wait_until("blocks released", || server.metrics.kv_blocks_in_use.load(Relaxed) == 0);
    server.shutdown();
}

/// A panic that escapes round-level containment (injected at the top
/// of the worker loop) costs the in-flight slots at most, never the
/// service: the supervisor restarts the loop, the pending queue
/// survives, every client is answered.
#[test]
fn worker_restart_preserves_service_and_answers_everyone() {
    let _g = faultpoint::scenario("worker.round=panic@3");
    let model = tiny_model();
    let server = Server::start(model, 2, Duration::from_millis(1), 7);
    let rxs: Vec<_> = (0..4u16)
        .map(|k| {
            let max_new = if k == 0 { 200 } else { 4 };
            server
                .submit_with(vec![5 + k, 6], max_new, 0.0, StopSet::none(), None)
                .expect("submit")
        })
        .collect();
    for (k, rx) in rxs.iter().enumerate() {
        let r = rx.recv_timeout(LONG).unwrap_or_else(|e| panic!("client {k} unanswered: {e:?}"));
        assert!(
            matches!(
                r.finish,
                FinishReason::Length | FinishReason::Failed | FinishReason::Cancelled
            ),
            "client {k}: {:?}",
            r.finish
        );
    }
    assert_eq!(server.metrics.worker_restarts.load(Relaxed), 1);
    let again = server.submit_with(vec![2, 3], 4, 0.0, StopSet::none(), None).expect("resubmit");
    assert_eq!(again.recv_timeout(LONG).expect("served").finish, FinishReason::Length);
    wait_until("blocks released", || server.metrics.kv_blocks_in_use.load(Relaxed) == 0);
    server.shutdown();
}

/// When every worker round panics, the supervisor burns its whole
/// restart budget, answers everything still queued, and exits —
/// clients see explicit responses or a closed channel (never a hang),
/// and later submissions are refused.
#[test]
fn restart_budget_exhaustion_answers_everyone_then_refuses() {
    let _g = faultpoint::scenario("worker.round=panic%100");
    let model = tiny_model();
    let server = Server::start(model, 2, Duration::from_millis(1), 7);
    let rxs: Vec<_> = (0..3u16)
        .filter_map(|k| server.submit_with(vec![5 + k, 6], 4, 0.0, StopSet::none(), None).ok())
        .collect();
    for (k, rx) in rxs.iter().enumerate() {
        match rx.recv_timeout(LONG) {
            Ok(r) => assert!(
                matches!(r.finish, FinishReason::Cancelled | FinishReason::Failed),
                "client {k}: {:?}",
                r.finish
            ),
            Err(mpsc::RecvTimeoutError::Disconnected) => {} // raced the worker's exit
            Err(e) => panic!("client {k} left hanging: {e:?}"),
        }
    }
    wait_until("restart budget exhausted", || {
        server.metrics.worker_restarts.load(Relaxed) == 3
    });
    wait_until("worker gone", || server.submit(vec![1], 1, 0.0).is_err());
    server.shutdown();
}

/// Soak: a burst of requests under allocation faults, deadlines and
/// client cancellations. Every request is answered and the pool ends
/// empty. `PALLAS_FAULTS`, when set (CI's seed matrix), replaces the
/// default plan — a failure replays from the spec string alone.
#[test]
fn soak_mixed_faults_deadlines_and_cancels_leak_nothing() {
    let spec = std::env::var("PALLAS_FAULTS")
        .unwrap_or_else(|_| "seed=11;kvpool.alloc=err%25".to_string());
    let _g = faultpoint::scenario(&spec);
    let model = tiny_model();
    let server = Server::start_with_opts(
        model,
        ServerOptions {
            max_batch: 3,
            batch_wait: Duration::from_millis(1),
            kv_block: 8,
            kv_pool_blocks: 10,
            stop: StopSet::none(),
            ..ServerOptions::default()
        },
    );
    let mut clients = Vec::new();
    for k in 0..24u16 {
        let plen = 1 + (k as usize * 5) % 7;
        let prompt: Vec<u16> = (0..plen as u16).map(|j| (j * 13 + k * 7) % 60).collect();
        let deadline_ms = if k % 3 == 0 { Some(15) } else { None };
        let (rx, cancel) = server
            .submit_qos_cancellable("default", prompt, 8, 0.0, None, None, deadline_ms)
            .expect("submit accepted");
        if k % 4 == 1 {
            cancel.cancel();
        }
        clients.push(rx);
    }
    for (k, rx) in clients.iter().enumerate() {
        assert!(rx.recv_timeout(LONG).is_ok(), "request {k} left unanswered");
    }
    wait_until("blocks released", || server.metrics.kv_blocks_in_use.load(Relaxed) == 0);
    server.shutdown();
}

/// An injected draft-model panic (fault site `spec.draft`) must
/// degrade the slot to *plain* decoding — speculation is an
/// optimization, never a correctness dependency. No quarantine, no
/// failed response, output bit-identical to a fault-free run.
#[test]
fn draft_panic_degrades_to_plain_decoding_not_quarantine() {
    use btc_llm::coordinator::SpecConfig;
    let model = tiny_model();
    let prompts: Vec<Vec<u16>> = vec![vec![5, 6, 7], vec![9, 8]];
    let want: Vec<Vec<u16>> = {
        let _iso = faultpoint::scenario("");
        let solo = Server::start(model.clone(), 1, Duration::from_millis(1), 7);
        let w = prompts.iter().map(|p| run_solo(&solo, p)).collect();
        solo.shutdown();
        w
    };
    let _g = faultpoint::scenario("spec.draft=panic%100");
    let server = Server::start_with_opts(
        model.clone(),
        ServerOptions {
            max_batch: 2,
            batch_wait: Duration::from_millis(20),
            seed: 7,
            spec: Some(SpecConfig::new(model, "twin", 3, 6)),
            ..ServerOptions::default()
        },
    );
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| server.submit_with(p.clone(), 6, 0.0, StopSet::none(), None).expect("submit"))
        .collect();
    for (rx, want) in rxs.iter().zip(&want) {
        let r = rx.recv_timeout(LONG).expect("degraded slot still answers");
        assert_eq!(r.finish, FinishReason::Length, "degrade, not failure");
        assert_eq!(&r.tokens[r.prompt_len..], &want[..], "bit-identical after degrade");
    }
    assert!(server.metrics.spec_degraded.load(Relaxed) >= 1, "degrade recorded");
    assert!(server.metrics.panics_caught.load(Relaxed) >= 1, "draft panic caught");
    assert_eq!(server.metrics.quarantines.load(Relaxed), 0, "no quarantine for a draft fault");
    wait_until("blocks released", || server.metrics.kv_blocks_in_use.load(Relaxed) == 0);
    server.shutdown();
}

/// An SSE write failure mid-stream (injected at the wire) trips the
/// request's cancel token: generation stops within a round, blocks
/// come back, and the front-end keeps serving new connections.
#[test]
fn tcp_write_failure_mid_stream_cancels_the_generation() {
    let _g = faultpoint::scenario("net.write=err@4");
    let model = tiny_model();
    let server = Arc::new(Server::start(model, 2, Duration::from_millis(1), 7));
    let metrics = server.metrics.clone();
    let net = NetServer::bind(server, "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = net.local_addr();
    let reply = post_generate(addr, r#"{"prompt":[5,6,7],"max_new":300,"stop":[],"stream":true}"#);
    assert!(reply.contains("200 OK"), "{reply}");
    assert_eq!(sse_tokens(&reply).len(), 3, "three events before the injected write failure");
    assert!(!reply.contains("\"done\""), "no terminal event on a dead stream:\n{reply}");
    assert!(metrics.disconnect_cancels.load(Relaxed) >= 1, "cancel token tripped");
    wait_until("blocks released", || metrics.kv_blocks_in_use.load(Relaxed) == 0);
    let reply = post_generate(addr, r#"{"prompt":[9,8],"max_new":3,"stop":[],"stream":true}"#);
    assert!(reply.contains("\"done\":true"), "follow-up client served:\n{reply}");
    net.shutdown(Duration::from_secs(5));
}

/// A request whose deadline expires while it waits for admission
/// (starved deterministically by a 100% allocation fault) is answered
/// over the wire as HTTP 200 with finish `deadline_exceeded`.
#[test]
fn tcp_deadline_expires_while_pending_under_alloc_pressure() {
    let _g = faultpoint::scenario("kvpool.alloc=err%100");
    let model = tiny_model();
    let server = Arc::new(Server::start(model, 2, Duration::from_millis(1), 7));
    let metrics = server.metrics.clone();
    let net = NetServer::bind(server, "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = net.local_addr();
    let reply =
        post_generate(addr, r#"{"prompt":[5,6],"max_new":8,"stream":false,"deadline_ms":60}"#);
    assert!(reply.contains("200 OK"), "{reply}");
    assert!(reply.contains("\"finish\":\"deadline_exceeded\""), "{reply}");
    assert!(metrics.deadline_cancels.load(Relaxed) >= 1);
    wait_until("blocks released", || metrics.kv_blocks_in_use.load(Relaxed) == 0);
    net.shutdown(Duration::from_secs(5));
}

/// Status-code mapping on the wire: a quarantined request is HTTP 500
/// with finish `failed`, and the fault counters all surface in
/// `/metrics`.
#[test]
fn tcp_failed_maps_to_500_and_metrics_expose_fault_counters() {
    let _iso = faultpoint::scenario("");
    let model = tiny_model();
    let server = Arc::new(Server::start(model, 2, Duration::from_millis(1), 7));
    let net = NetServer::bind(server, "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = net.local_addr();
    let reply = post_generate(addr, r#"{"prompt":[999],"stream":false}"#);
    assert!(reply.contains("500 Internal Server Error"), "{reply}");
    assert!(reply.contains("\"finish\":\"failed\""), "{reply}");
    let metrics = raw_roundtrip(addr, "GET /metrics HTTP/1.1\r\n\r\n");
    for key in [
        "panics_caught=",
        "quarantines=",
        "worker_restarts=",
        "deadline_cancels=",
        "disconnect_cancels=",
    ] {
        assert!(metrics.contains(key), "missing {key} in:\n{metrics}");
    }
    net.shutdown(Duration::from_secs(5));
}

/// Backpressure on the wire: with the lone pending slot occupied (and
/// admission starved by a 100% allocation fault), an overflowing
/// tenant gets HTTP 429 carrying `Retry-After` — and the queued
/// request itself is still answered when its own deadline expires.
#[test]
fn tcp_backpressure_sends_retry_after() {
    let _g = faultpoint::scenario("kvpool.alloc=err%100");
    let model = tiny_model();
    let qos = QosConfig {
        admission: AdmitPolicy::Fifo,
        eviction: EvictionKind::Newest,
        tenants: vec![TenantSpec {
            id: "default".to_string(),
            weight: 1,
            priority: 0,
            max_pending: 1,
        }],
    };
    let server = Arc::new(Server::start_with_opts(
        model,
        ServerOptions { max_batch: 1, qos, ..ServerOptions::default() },
    ));
    let (rx1, _cancel) = server
        .submit_qos_cancellable("default", vec![1, 2], 2, 0.0, None, None, Some(2000))
        .expect("first request queues");
    let net = NetServer::bind(server, "127.0.0.1:0", NetOptions::default()).expect("bind");
    let addr = net.local_addr();
    let reply = post_generate(addr, r#"{"prompt":[3,4],"max_new":2,"stream":false}"#);
    assert!(reply.contains("429 Too Many Requests"), "{reply}");
    assert!(reply.contains("Retry-After: 1"), "429 carries a backoff hint:\n{reply}");
    let r1 = rx1.recv_timeout(LONG).expect("queued request answered");
    assert_eq!(r1.finish, FinishReason::DeadlineExceeded);
    net.shutdown(Duration::from_secs(5));
}
