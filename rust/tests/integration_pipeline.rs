//! Integration: full quantization pipeline on the real trained
//! artifacts, checking the paper's quality orderings end-to-end.
//! Skips (with a loud message) when `make artifacts` has not run.

use btc_llm::benchsuite::{eval_lane, load_workload, Workload};
use btc_llm::quant::pipeline::QuantConfig;

fn workload() -> Option<Workload> {
    match load_workload("tinylm_s") {
        Ok(w) => Some(w),
        Err(e) => {
            eprintln!("SKIP integration_pipeline: {e}");
            None
        }
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "pipeline-heavy; run with cargo test --release")]
fn quality_ordering_across_methods() {
    let Some(w) = workload() else { return };
    let toks = 1200;
    let fp = eval_lane(&w, &QuantConfig::fp16(), toks, None).unwrap();
    let btc = eval_lane(&w, &QuantConfig::btc(1.11), toks, None).unwrap();
    let arb = eval_lane(&w, &QuantConfig::arb_llm(), toks, None).unwrap();
    let naive = eval_lane(&w, &QuantConfig::naive(), toks, None).unwrap();
    // Paper Table 1 ordering at ~1 bit: FP16 < BTC <= ARB < naive.
    assert!(fp.ppl < btc.ppl, "fp {} !< btc {}", fp.ppl, btc.ppl);
    assert!(btc.ppl <= arb.ppl * 1.02, "btc {} !<= arb {}", btc.ppl, arb.ppl);
    assert!(arb.ppl < naive.ppl, "arb {} !< naive {}", arb.ppl, naive.ppl);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "pipeline-heavy; run with cargo test --release")]
fn btc_degrades_gracefully_with_bits() {
    let Some(w) = workload() else { return };
    let toks = 1200;
    let mut prev = 0.0;
    for bits in [1.11, 0.9, 0.8, 0.7] {
        let r = eval_lane(&w, &QuantConfig::btc(bits), toks, None).unwrap();
        assert!(r.ppl.is_finite() && r.ppl > 1.0);
        assert!(
            r.ppl >= prev * 0.95,
            "ppl should not improve as bits shrink: {bits} -> {}",
            r.ppl
        );
        // Never collapses (paper: BTC robust where VQ explodes).
        assert!(r.ppl < 60.0, "collapse at {bits} bits: {}", r.ppl);
        prev = r.ppl;
    }
}

#[test]
#[cfg_attr(debug_assertions, ignore = "pipeline-heavy; run with cargo test --release")]
fn fpvq_collapses_sub_one_bit() {
    let Some(w) = workload() else { return };
    let toks = 800;
    let two = eval_lane(&w, &QuantConfig::fpvq(2.0), toks, None).unwrap();
    let sub = eval_lane(&w, &QuantConfig::fpvq(0.7), toks, None).unwrap();
    // The paper's VPTQ/GPTVQ rows: fine at 2 bits, collapse below 1.
    assert!(two.ppl < 3.0, "fp-vq@2b should be near-lossless: {}", two.ppl);
    assert!(sub.ppl > two.ppl * 1.5, "fp-vq@0.7 should degrade hard: {}", sub.ppl);
}

#[test]
#[cfg_attr(debug_assertions, ignore = "pipeline-heavy; run with cargo test --release")]
fn payload_bits_honest() {
    let Some(w) = workload() else { return };
    let toks = 400;
    let btc = eval_lane(&w, &QuantConfig::btc(0.8), toks, None).unwrap();
    assert!(btc.payload_bits < 1.0, "BTC sub-1 payload {}", btc.payload_bits);
    let stb = eval_lane(&w, &QuantConfig::stbllm(0.8), toks, None).unwrap();
    assert!(stb.payload_bits > 1.0, "STBLLM mask overhead hidden: {}", stb.payload_bits);
}

#[test]
fn zeroshot_above_chance_for_fp() {
    let Some(w) = workload() else { return };
    let fp = eval_lane(&w, &QuantConfig::fp16(), 400, Some(32)).unwrap();
    // The trained model must actually know the grammar (well above 50%).
    assert!(fp.mean_acc.unwrap() > 60.0, "fp mean acc {}", fp.mean_acc.unwrap());
}

#[test]
#[cfg_attr(debug_assertions, ignore = "pipeline-heavy; run with cargo test --release")]
fn gqa_family_quantizes() {
    let Some(w) = (match load_workload("tinyqwen_s") {
        Ok(w) => Some(w),
        Err(e) => {
            eprintln!("SKIP gqa_family_quantizes: {e}");
            None
        }
    }) else {
        return;
    };
    let r = eval_lane(&w, &QuantConfig::btc(0.8), 800, None).unwrap();
    assert!(r.ppl.is_finite() && r.ppl < 60.0);
}
