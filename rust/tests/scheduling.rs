//! Integration: the continuous-batching scheduler under concurrent
//! multi-threaded submitters. Hermetic — runs on a synthetic
//! serving-shaped model (no trained artifacts needed).
//!
//! The load-bearing assertion is the determinism contract: with
//! greedy sampling, every request's output is bit-identical to an
//! isolated single-request run, no matter how the requests interleave
//! in flight (mixed prompt/generation lengths, threaded submitters,
//! chunked prefills).

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use btc_llm::coordinator::{CancelToken, GenRequest, Scheduler, Server, ServerOptions, StopSet};
use btc_llm::io::weights::ModelConfig;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::fixture::synth_raw_model;
use btc_llm::util::rng::Rng;

fn tiny_serving_model() -> btc_llm::model::Transformer {
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 32,
        n_layer: 2,
        n_head: 4,
        n_kv_head: 2,
        d_ff: 64,
        max_seq: 128,
        rope_theta: 10000.0,
    };
    let (raw, corpus) = synth_raw_model(3, cfg);
    let mut qm = quantize_model(&raw, &corpus, &QuantConfig::fp16()).expect("quantize fp16");
    qm.model.prepare_engines();
    qm.model
}

/// Mixed workload: prompt lengths 1..=12, generation lengths 1..=6.
fn jobs() -> Vec<(Vec<u16>, usize)> {
    (0..16u16)
        .map(|k| {
            let plen = 1 + ((k as usize * 7) % 12);
            let prompt: Vec<u16> =
                (0..plen).map(|j| ((j * 11 + k as usize * 5) % 60) as u16).collect();
            let max_new = 1 + (k as usize % 6);
            (prompt, max_new)
        })
        .collect()
}

#[test]
fn concurrent_submitters_all_complete_and_match_solo() {
    let model = tiny_serving_model();
    let jobs = jobs();

    // Isolated single-request references (one slot, whole-prompt
    // prefill): the ground truth each in-flight run must reproduce.
    let solo_server = Server::start(model.clone(), 1, Duration::from_millis(1), 7);
    let solo: Vec<Vec<u16>> = jobs
        .iter()
        .map(|(p, m)| {
            solo_server
                .submit_with(p.clone(), *m, 0.0, StopSet::none(), None)
                .expect("submit")
                .recv_timeout(Duration::from_secs(120))
                .expect("solo response")
                .tokens
        })
        .collect();
    solo_server.shutdown();

    // Same jobs from 4 OS threads against one server with small
    // prefill chunks, so admissions land mid-flight.
    let server = Server::start_with_opts(
        model,
        ServerOptions {
            max_batch: 4,
            prefill_chunk: 3,
            batch_wait: Duration::from_millis(2),
            seed: 7,
            ..ServerOptions::default()
        },
    );
    let results: Vec<Vec<u16>> = std::thread::scope(|s| {
        let server = &server;
        let handles: Vec<_> = jobs
            .chunks(4)
            .map(|chunk| {
                s.spawn(move || {
                    // Enqueue the whole chunk first, then collect: the
                    // queue stays deep while requests are in flight, so
                    // decode rounds genuinely fuse multiple requests.
                    let rxs: Vec<_> = chunk
                        .iter()
                        .map(|(p, m)| {
                            server
                                .submit_with(p.clone(), *m, 0.0, StopSet::none(), None)
                                .expect("submit")
                        })
                        .collect();
                    rxs.into_iter()
                        .map(|rx| {
                            rx.recv_timeout(Duration::from_secs(120)).expect("response").tokens
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("submitter thread")).collect()
    });

    assert_eq!(results.len(), jobs.len(), "every request got a response");
    for (i, (got, want)) in results.iter().zip(&solo).enumerate() {
        assert_eq!(got, want, "request {i} diverged from its isolated run");
    }
    assert_eq!(
        server.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        jobs.len() as u64
    );
    // In-flight serving actually interleaved (some decode round fused
    // more than one request) and the per-request stamps were recorded.
    assert!(server.metrics.mean_batch_size() > 1.0, "requests overlapped in flight");
    assert!(server.metrics.ttft_percentile_us(0.5) > 0);
    server.shutdown();
}

#[test]
fn tight_kv_pool_preserves_determinism_under_load() {
    // The same mixed workload as above, but through a KV pool far too
    // small to hold every request's worst case at once (8 blocks x 8
    // positions = 64 vs ~16 requests x up to 18 positions): admission
    // defers, growth preempts — and every greedy output must STILL be
    // bit-identical to its isolated run, because deferral recomputes
    // nothing and preemption re-prefills exactly the dropped tokens.
    let model = tiny_serving_model();
    let jobs = jobs();
    let solo_server = Server::start(model.clone(), 1, Duration::from_millis(1), 7);
    let solo: Vec<Vec<u16>> = jobs
        .iter()
        .map(|(p, m)| {
            solo_server
                .submit_with(p.clone(), *m, 0.0, StopSet::none(), None)
                .expect("submit")
                .recv_timeout(Duration::from_secs(120))
                .expect("solo response")
                .tokens
        })
        .collect();
    solo_server.shutdown();

    let server = Server::start_with_opts(
        model,
        ServerOptions {
            max_batch: 4,
            prefill_chunk: 4,
            batch_wait: Duration::from_millis(2),
            seed: 7,
            kv_block: 8,
            kv_pool_blocks: 8,
            ..ServerOptions::default()
        },
    );
    let rxs: Vec<_> = jobs
        .iter()
        .map(|(p, m)| {
            server.submit_with(p.clone(), *m, 0.0, StopSet::none(), None).expect("submit")
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(Duration::from_secs(120)).expect("response under pool pressure");
        assert_eq!(r.tokens, solo[i], "request {i} diverged under a tight KV pool");
    }
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(server.metrics.completed.load(Relaxed), jobs.len() as u64);
    assert!(
        server.metrics.kv_blocks_peak.load(Relaxed) <= 8,
        "pool budget respected: {}",
        server.metrics.kv_blocks_peak.load(Relaxed)
    );
    server.shutdown();
}

#[test]
fn no_head_of_line_blocking_under_real_pipeline() {
    // Drive the scheduler directly over the real quantized pipeline
    // model: the interleaving is deterministic (no wall-clock races),
    // and the streamed tokens double as the progress proof.
    let model = tiny_serving_model();
    let metrics = Arc::new(btc_llm::coordinator::metrics::Metrics::new());
    let mut sched = Scheduler::new(model, metrics, 2, 4);
    let mut rng = Rng::new(7);
    let (long_stream_tx, long_stream) = mpsc::channel();
    let (ltx, lrx) = mpsc::channel();
    sched.admit(GenRequest {
        prompt: vec![1, 2, 3, 4, 5],
        max_new_tokens: 96,
        temperature: 0.0,
        stop: StopSet::none(),
        stream: Some(long_stream_tx),
        respond: ltx,
        submitted: Instant::now(),
        tenant: 0,
        deadline: None,
        cancel: CancelToken::default(),
    });
    // A few rounds in, the long request is mid-decode (prompt chunked
    // 4+1, then decoding) — now the short one arrives.
    for _ in 0..4 {
        sched.step(&mut rng);
    }
    assert!(long_stream.try_iter().count() >= 1, "long request is producing tokens");
    let (stx, srx) = mpsc::channel();
    sched.admit(GenRequest {
        prompt: vec![9, 8],
        max_new_tokens: 3,
        temperature: 0.0,
        stop: StopSet::none(),
        stream: None,
        respond: stx,
        submitted: Instant::now(),
        tenant: 0,
        deadline: None,
        cancel: CancelToken::default(),
    });
    let mut rounds = 0;
    while !sched.is_idle() {
        sched.step(&mut rng);
        rounds += 1;
        assert!(rounds < 1000, "scheduler failed to drain");
    }
    let short = srx.try_recv().expect("short response");
    let long = lrx.try_recv().expect("long response");
    assert!(
        short.seq < long.seq,
        "short request (seq {}) must retire before the long one (seq {})",
        short.seq,
        long.seq
    );
    assert_eq!(long.tokens.len() - long.prompt_len, 96);
    assert_eq!(short.tokens.len() - short.prompt_len, 3);
}
