//! Integration: the QLM1 v2 container round-trips **every** backend
//! kind — quantize each lane on a hermetic fixture, save, reload, and
//! require bit-identical reconstructed weights and forward logits.
//! (Hermetic: no artifacts needed.)

use btc_llm::data::corpus;
use btc_llm::io::qweights;
use btc_llm::model::Transformer;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::fixture::tiny_raw_model;

fn quick(cfg: QuantConfig) -> QuantConfig {
    QuantConfig {
        calib_seqs: 4,
        calib_seq_len: 24,
        calib_rows: 48,
        transform_outer: 2,
        arb_iters: 4,
        v: 8,
        ..cfg
    }
}

#[test]
fn qlm_roundtrips_every_backend_kind_bit_identically() {
    let (raw, text) = tiny_raw_model(9);
    let dir = std::env::temp_dir().join("btc_qlm_roundtrip_test");
    std::fs::create_dir_all(&dir).unwrap();
    let toks: Vec<u16> = corpus::generate(200, 3).bytes().take(16).map(|b| b as u16).collect();

    let lanes: [(QuantConfig, &str); 6] = [
        (QuantConfig::fp16(), "dense"),
        (QuantConfig::naive(), "binary"),
        (QuantConfig::arb_llm(), "residual"),
        (QuantConfig::stbllm(0.8), "nm-sparse"),
        (QuantConfig::fpvq(2.0), "fp-vq"),
        (QuantConfig::btc(0.8), "codebook"),
    ];
    for (cfg, expect_tag) in lanes {
        let qm = quantize_model(&raw, &text, &quick(cfg)).unwrap();
        assert_eq!(
            qm.model.blocks[0].wq.backend_name(),
            expect_tag,
            "{} produced an unexpected backend",
            qm.stats.method
        );
        let path = dir.join(format!("{expect_tag}.qlm"));
        qweights::save(&path, &qm.model).unwrap();

        let mut reloaded = Transformer::from_raw(&raw).unwrap();
        qweights::load_into(&path, &mut reloaded).unwrap();

        // Every linear: reconstructed weights must be bit-identical.
        for (ba, bb) in qm.model.blocks.iter().zip(reloaded.blocks.iter()) {
            for ((name, la), (_, lb)) in ba.linears().iter().zip(bb.linears().iter()) {
                assert_eq!(la.backend.tag(), lb.backend.tag(), "{expect_tag}/{name}");
                assert_eq!(
                    la.backend.reconstruct().data,
                    lb.backend.reconstruct().data,
                    "{expect_tag}/{name}: reconstruction not bit-identical"
                );
            }
        }

        // Forward logits: bit-identical through the same eval path.
        reloaded.cache_dense_all();
        let a = qm.model.forward(&toks);
        let b = reloaded.forward(&toks);
        assert_eq!(a.data, b.data, "{expect_tag}: logits not bit-identical after reload");
    }
}
