//! Wire back-compat: a **committed** QLM1 v2 byte fixture (generated
//! by `rust/tests/fixtures/make_golden_v2.py` — the pre-packed-plane
//! layout with u64 codebook words, dense u32 indices and f32 scales)
//! must keep loading bit-identically after the v3 bump, and must
//! survive a v2 -> v3 re-save round trip unchanged.
//!
//! The fixture's scale values are exactly f16-representable, so the
//! load-time f32 -> f16 rounding is lossless and every comparison here
//! is exact equality, not a tolerance.

use std::path::PathBuf;
use std::sync::Arc;

use btc_llm::model::Transformer;
use btc_llm::quant::codebook::{BinaryCodebook, CodebookLayer};
use btc_llm::util::fixture::tiny_raw_model;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/fixtures/qlm_v2_codebook.qlm")
}

/// The exact content `make_golden_v2.py` wrote into the fixture.
fn golden_layer() -> CodebookLayer {
    let cb = Arc::new(BinaryCodebook { v: 8, words: vec![0x00, 0xFF, 0x0F, 0x3C] });
    let idx: Vec<u32> = (0..32).map(|i| (i * 7) % 4).collect();
    let alpha: Vec<f32> = (0..16).map(|i| 0.5 + (i % 8) as f32 * 0.25).collect();
    let mu: Vec<f32> = (0..16).map(|i| (i % 4) as f32 * 0.125 - 0.25).collect();
    CodebookLayer::new(16, 16, cb, &idx, &alpha, &mu, &[0u16; 16], 1)
}

#[test]
fn golden_v2_file_loads_bit_identically() {
    let (raw, _) = tiny_raw_model(5);
    let mut m = Transformer::from_raw(&raw).unwrap();
    btc_llm::io::qweights::load_into(&fixture_path(), &mut m).unwrap();

    assert_eq!(m.blocks[0].wq.backend_name(), "codebook");
    let got = m.blocks[0]
        .wq
        .backend
        .as_any()
        .downcast_ref::<CodebookLayer>()
        .expect("codebook backend");
    let want = golden_layer();
    // Indices survive the dense-u32 -> packed-plane conversion exactly.
    assert_eq!(got.idx, want.idx);
    // f32 scales round to the same f16 bits the in-memory format uses.
    assert_eq!(got.alpha, want.alpha);
    assert_eq!(got.mu, want.mu);
    assert_eq!(got.n_groups, 1);
    assert_eq!(got.codebook.words, want.codebook.words);
    // And the dequantized weight is bit-identical.
    assert_eq!(got.reconstruct().data, want.reconstruct().data);
}

#[test]
fn golden_v2_survives_v3_resave_round_trip() {
    let (raw, _) = tiny_raw_model(5);
    let mut m = Transformer::from_raw(&raw).unwrap();
    btc_llm::io::qweights::load_into(&fixture_path(), &mut m).unwrap();

    let dir = std::env::temp_dir().join("btc_qlm_golden_test");
    std::fs::create_dir_all(&dir).unwrap();
    let v3_path = dir.join("resaved_v3.qlm");
    btc_llm::io::qweights::save(&v3_path, &m).unwrap();

    let mut reloaded = Transformer::from_raw(&raw).unwrap();
    btc_llm::io::qweights::load_into(&v3_path, &mut reloaded).unwrap();
    let a = m.blocks[0].wq.backend.as_any().downcast_ref::<CodebookLayer>().unwrap();
    let b = reloaded.blocks[0].wq.backend.as_any().downcast_ref::<CodebookLayer>().unwrap();
    assert_eq!(a.idx, b.idx);
    assert_eq!(a.alpha, b.alpha);
    assert_eq!(a.mu, b.mu);
    assert_eq!(a.codebook.words, b.codebook.words);
    assert_eq!(a.reconstruct().data, b.reconstruct().data);

    // The v3 record for this layer is strictly smaller on the wire
    // than the v2 encoding it came from: 2-bit packed indices instead
    // of u32s, u16 scales instead of f32s, v-bit codebook centroids
    // instead of u64 words.
    use btc_llm::model::WeightBackend;
    let v2_payload_bytes = 12 + 32 * 4 + 16 * 4 + 16 * 4 + 16 * 2;
    let v3_payload_bytes = a.wire_bytes();
    assert_eq!(v3_payload_bytes, 12 + (32 * 2usize).div_ceil(8) + 32 * 2);
    assert!(v3_payload_bytes * 3 < v2_payload_bytes, "{v3_payload_bytes} vs {v2_payload_bytes}");
}
