//! Integration: PJRT runtime loads the AOT HLO artifacts and the Rust
//! engines match their numerics (the compact version of
//! examples/hlo_parity.rs, kept in `cargo test`). Skips without
//! artifacts.

use btc_llm::bitops::BitMatrix;
use btc_llm::engine::{BinaryGemmEngine, EngineCtx};
use btc_llm::io::load_model;
use btc_llm::model::Transformer;
use btc_llm::quant::binarize::BinaryLayer;
use btc_llm::runtime::{PjrtRuntime, TensorArg};
use btc_llm::tensor::Matrix;
use btc_llm::util::proptest::assert_close;
use btc_llm::util::rng::Rng;

fn runtime() -> Option<PjrtRuntime> {
    let dir = btc_llm::artifacts_dir();
    if !dir.join("binary_gemm.hlo.txt").exists() {
        eprintln!("SKIP runtime_parity: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(PjrtRuntime::cpu(&dir).expect("PJRT CPU client"))
}

#[test]
fn binary_gemm_kernel_parity() {
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let (m, n, o) = (8usize, 96usize, 64usize);
    let x = Matrix::randn(m, n, &mut rng);
    let signs: Vec<f32> = (0..o * n).map(|_| rng.sign()).collect();
    let alpha: Vec<f32> = (0..o).map(|_| rng.range_f32(0.2, 2.0)).collect();
    let mu: Vec<f32> = (0..o).map(|_| rng.normal() * 0.1).collect();
    let jax = rt
        .run_f32(
            "binary_gemm.hlo.txt",
            &[
                TensorArg::F32(vec![m, n], x.data.clone()),
                TensorArg::F32(vec![o, n], signs.clone()),
                TensorArg::F32(vec![o], alpha.clone()),
                TensorArg::F32(vec![o], mu.clone()),
            ],
        )
        .unwrap();
    let layer = BinaryLayer {
        rows: o,
        cols: n,
        b: BitMatrix::from_signs(o, n, &signs),
        alpha,
        mu,
        col_group: vec![0; n],
        n_groups: 1,
    };
    let rust = BinaryGemmEngine::with_ctx(&layer, &EngineCtx::current()).forward(&x);
    assert_close(&rust.data, &jax, 1e-3, 1e-3).unwrap();
}

#[test]
fn model_forward_parity() {
    let Some(mut rt) = runtime() else { return };
    let dir = btc_llm::artifacts_dir();
    let seq = 32usize;
    let tokens: Vec<u16> = (0..seq).map(|i| (35 + (i * 11) % 70) as u16).collect();
    let raw = load_model(&dir.join("tinylm_s.bin")).unwrap();
    let mut args =
        vec![TensorArg::I32(vec![1, seq], tokens.iter().map(|&t| t as i32).collect())];
    for (_, (dims, data)) in raw.tensors.iter() {
        args.push(TensorArg::F32(dims.clone(), data.clone()));
    }
    let jax = rt.run_f32("tinylm_s_fwd.hlo.txt", &args).unwrap();
    let model = Transformer::from_raw(&raw).unwrap();
    let rust = model.forward(&tokens);
    assert_close(&rust.data, &jax, 5e-2, 5e-3).unwrap();
}

#[test]
fn runtime_caches_executables() {
    let Some(mut rt) = runtime() else { return };
    rt.load("binary_gemm.hlo.txt").unwrap();
    rt.load("binary_gemm.hlo.txt").unwrap(); // second load = cache hit
    assert_eq!(rt.loaded().len(), 1);
}

#[test]
fn missing_artifact_is_error() {
    let Some(mut rt) = runtime() else { return };
    assert!(rt.load("does_not_exist.hlo.txt").is_err());
}
