//! Integration: speculative decoding bit-identity (DESIGN.md §13).
//!
//! Greedy outputs with speculation ON must be bit-identical to the
//! plain target-only path. Speculation only changes how many tokens
//! one scheduling round yields — never which tokens. Pinned here
//! across quantization backends (fp16 / binary / btc targets under a
//! btc-0.8 draft), mixed co-traffic with sampled lanes, pool pressure
//! that defers/preempts a speculating slot mid-stream, and a
//! deliberately-disagreeing draft whose every proposal is rejected.

use std::sync::atomic::Ordering::Relaxed;
use std::time::Duration;

use btc_llm::coordinator::{Server, ServerOptions, SpecConfig, StopSet};
use btc_llm::io::weights::ModelConfig;
use btc_llm::model::Transformer;
use btc_llm::quant::pipeline::{quantize_model, registry, QuantConfig};
use btc_llm::util::fixture::synth_raw_model;

const LONG: Duration = Duration::from_secs(120);

fn serving_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 64,
        d_model: 32,
        n_layer: 2,
        n_head: 4,
        n_kv_head: 2,
        d_ff: 64,
        max_seq: 128,
        rope_theta: 10000.0,
    }
}

/// Quantize a synthetic checkpoint (`seed`) with the given method.
/// Every model here shares the serving shape, so any two of them form
/// a valid target/draft pair; same seed = same checkpoint, the
/// deployment story (one raw model, two bit-widths).
fn quantized(seed: u64, qcfg: &QuantConfig) -> Transformer {
    let (raw, corpus) = synth_raw_model(seed, serving_cfg());
    let mut qcfg = qcfg.clone();
    // Serving arms activation quantization at the engine boundary, not
    // in the pipeline (same convention as `cmd_serve`).
    qcfg.act_bits = 16;
    let mut qm = quantize_model(&raw, &corpus, &qcfg).expect("quantize");
    qm.model.prepare_engines();
    qm.model
}

fn btc_08() -> QuantConfig {
    registry::get_with_bits("btc", Some(0.8)).expect("btc-0.8 preset")
}

/// Mixed workload: prompt lengths 1..=10, generation lengths 3..=10.
fn jobs(n: u16) -> Vec<(Vec<u16>, usize)> {
    (0..n)
        .map(|k| {
            let plen = 1 + ((k as usize * 7) % 10);
            let prompt: Vec<u16> =
                (0..plen).map(|j| ((j * 11 + k as usize * 5) % 60) as u16).collect();
            (prompt, 3 + (k as usize % 8))
        })
        .collect()
}

/// Isolated single-request references on a plain (non-speculative)
/// server: the ground truth every speculative run must reproduce.
fn solo_refs(model: &Transformer, jobs: &[(Vec<u16>, usize)]) -> Vec<Vec<u16>> {
    let solo = Server::start(model.clone(), 1, Duration::from_millis(1), 7);
    let out = jobs
        .iter()
        .map(|(p, m)| {
            solo.submit_with(p.clone(), *m, 0.0, StopSet::none(), None)
                .expect("submit")
                .recv_timeout(LONG)
                .expect("solo response")
                .tokens
        })
        .collect();
    solo.shutdown();
    out
}

fn run_and_compare(server: &Server, jobs: &[(Vec<u16>, usize)], want: &[Vec<u16>], label: &str) {
    let rxs: Vec<_> = jobs
        .iter()
        .map(|(p, m)| {
            server.submit_with(p.clone(), *m, 0.0, StopSet::none(), None).expect("submit")
        })
        .collect();
    for (i, (rx, want)) in rxs.into_iter().zip(want).enumerate() {
        let r = rx.recv_timeout(LONG).expect("response");
        assert_eq!(&r.tokens, want, "{label}: request {i} diverged from its plain run");
    }
}

#[test]
fn spec_on_equals_off_across_backends() {
    for (name, qcfg) in [
        ("fp16", QuantConfig::fp16()),
        ("binary", registry::get_with_bits("arb-llm", Some(1.0)).expect("arb-llm preset")),
        ("btc-1.11", registry::get_with_bits("btc", Some(1.11)).expect("btc-1.11 preset")),
    ] {
        let target = quantized(3, &qcfg);
        let draft = quantized(3, &btc_08());
        let jobs = jobs(8);
        let want = solo_refs(&target, &jobs);
        let server = Server::start_with_opts(
            target,
            ServerOptions {
                max_batch: 4,
                batch_wait: Duration::from_millis(20),
                prefill_chunk: 4,
                seed: 7,
                spec: Some(SpecConfig::new(draft, "btc-0.8", 3, 6)),
                ..ServerOptions::default()
            },
        );
        run_and_compare(&server, &jobs, &want, name);
        assert!(
            server.metrics.spec_rounds.load(Relaxed) >= 1,
            "{name}: speculation actually ran"
        );
        // Every speculative round yields at least the bonus token.
        assert!(server.metrics.mean_spec_accepted() >= 1.0, "{name}");
        server.shutdown();
    }
}

#[test]
fn pool_pressure_preempting_speculating_slots_preserves_bit_identity() {
    // An agreeing draft (the target itself) makes every slot
    // speculate deeply, while the pool is far too small for four
    // slots' target + draft caches at once: speculative rounds hit
    // capacity walls, fall back, defer, and preempt mid-stream — and
    // every output must still match its isolated plain run.
    let target = quantized(3, &QuantConfig::fp16());
    let draft = target.clone();
    let jobs = jobs(16);
    let want = solo_refs(&target, &jobs);
    let server = Server::start_with_opts(
        target,
        ServerOptions {
            max_batch: 4,
            batch_wait: Duration::from_millis(2),
            prefill_chunk: 4,
            seed: 7,
            kv_block: 8,
            kv_pool_blocks: 8,
            spec: Some(SpecConfig::new(draft, "twin", 4, 8)),
            ..ServerOptions::default()
        },
    );
    run_and_compare(&server, &jobs, &want, "tight-pool");
    let m = &server.metrics;
    assert!(m.kv_blocks_peak.load(Relaxed) <= 8, "pool budget respected");
    assert!(
        m.kv_round_deferrals.load(Relaxed) + m.kv_preemptions.load(Relaxed) >= 1,
        "the pool actually pushed back"
    );
    assert_eq!(m.completed.load(Relaxed), jobs.len() as u64);
    server.shutdown();
}

#[test]
fn disagreeing_draft_still_terminates_and_matches() {
    // A draft from a *different* checkpoint (same shape, seed 99):
    // its proposals are effectively noise, so rounds accept ~0 drafts
    // — generation must still terminate (the verify forward always
    // yields the bonus token) and stay bit-identical.
    let target = quantized(3, &QuantConfig::fp16());
    let draft = quantized(99, &QuantConfig::fp16());
    let jobs = jobs(6);
    let want = solo_refs(&target, &jobs);
    let server = Server::start_with_opts(
        target,
        ServerOptions {
            max_batch: 3,
            batch_wait: Duration::from_millis(20),
            seed: 7,
            spec: Some(SpecConfig::new(draft, "noise", 4, 8)),
            ..ServerOptions::default()
        },
    );
    run_and_compare(&server, &jobs, &want, "disagreeing-draft");
    let m = &server.metrics;
    assert!(m.spec_rounds.load(Relaxed) >= 1, "speculation ran");
    assert!(m.mean_spec_accepted() >= 1.0, "every round still emits the bonus token");
    server.shutdown();
}

#[test]
fn sampled_cotraffic_bypasses_speculation_and_greedy_stays_exact() {
    // temperature > 0 lanes bypass speculation entirely; greedy lanes
    // sharing the batch keep the exactness contract.
    let target = quantized(3, &QuantConfig::fp16());
    let draft = target.clone();
    let greedy = jobs(4);
    let want = solo_refs(&target, &greedy);
    let server = Server::start_with_opts(
        target,
        ServerOptions {
            max_batch: 4,
            batch_wait: Duration::from_millis(20),
            seed: 7,
            spec: Some(SpecConfig::new(draft, "twin", 3, 6)),
            ..ServerOptions::default()
        },
    );
    let sampled: Vec<_> = (0..4u16)
        .map(|k| {
            server
                .submit_with(vec![5 + k, 6, 7], 6, 0.8, StopSet::none(), None)
                .expect("submit sampled")
        })
        .collect();
    run_and_compare(&server, &greedy, &want, "greedy-under-sampled-cotraffic");
    for rx in sampled {
        let r = rx.recv_timeout(LONG).expect("sampled lane completes");
        assert_eq!(r.tokens.len() - r.prompt_len, 6);
    }
    server.shutdown();
}
