//! The batched serving hot path must be *bit-identical* to the
//! sequential single-request path, per backend lane:
//!
//! - `prefill` ≡ repeated `decode_step` (last-token logits AND the
//!   K/V cache contents), and
//! - `decode_batch` ≡ per-request `decode_step` for mixed-length
//!   batches,
//!
//! across the dense (fp16), binary (sign-GEMM) and BTC codebook
//! (LUT-GEMM) backends, with the real serving engines prepared. All
//! on the hermetic fixture, so this runs without `make artifacts`.

use btc_llm::model::kvcache::{KvCache, KvPool, PagedKvCache, PoolConfig};
use btc_llm::model::Transformer;
use btc_llm::quant::kvquant::KvQuantConfig;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::fixture::tiny_raw_model;
use btc_llm::util::rng::Rng;

fn lanes() -> Vec<(&'static str, QuantConfig)> {
    let mut btc = QuantConfig::btc(0.8);
    btc.transform_outer = 2; // keep the fixture quantization fast
    vec![("fp16", QuantConfig::fp16()), ("binary", QuantConfig::naive()), ("btc", btc)]
}

fn lane_model(cfg: &QuantConfig) -> Transformer {
    let (raw, corpus) = tiny_raw_model(21);
    let mut qm = quantize_model(&raw, &corpus, cfg).expect("quantize fixture");
    qm.model.prepare_engines(); // the real serving engines
    qm.model
}

fn assert_caches_identical(label: &str, a: &KvCache, b: &KvCache) {
    assert_eq!(a.layers.len(), b.layers.len());
    for (li, (la, lb)) in a.layers.iter().zip(b.layers.iter()).enumerate() {
        assert_eq!(la.len, lb.len, "{label}: layer {li} position count");
        assert_eq!(la.k, lb.k, "{label}: layer {li} K payload");
        assert_eq!(la.v, lb.v, "{label}: layer {li} V payload");
    }
}

#[test]
fn prefill_equals_repeated_decode_step_all_backends() {
    let mut rng = Rng::new(3);
    for (label, cfg) in lanes() {
        let model = lane_model(&cfg);
        for trial in 0..3 {
            let len = 1 + rng.below(10);
            let prompt: Vec<u16> = (0..len).map(|_| rng.below(128) as u16).collect();
            let cap = prompt.len() + 4;
            let mut c_fast = model.new_cache(cap);
            let fast = model.prefill(&prompt, &mut c_fast);
            let mut c_slow = model.new_cache(cap);
            let mut slow = Vec::new();
            for &t in &prompt {
                slow = model.decode_step(t, &mut c_slow);
            }
            assert_eq!(fast, slow, "{label} trial {trial}: prefill logits differ");
            assert_caches_identical(label, &c_fast, &c_slow);
        }
    }
}

#[test]
fn decode_batch_equals_per_request_decode_step_all_backends() {
    let mut rng = Rng::new(4);
    for (label, cfg) in lanes() {
        let model = lane_model(&cfg);
        // Mixed-length histories, then 3 fused decode rounds.
        let bsz = 4usize;
        let histories: Vec<Vec<u16>> = (0..bsz)
            .map(|b| (0..b + 1).map(|_| rng.below(128) as u16).collect())
            .collect();
        let cap = 16;
        let mut batch_caches: Vec<KvCache> = (0..bsz).map(|_| model.new_cache(cap)).collect();
        let mut solo_caches: Vec<KvCache> = (0..bsz).map(|_| model.new_cache(cap)).collect();
        for b in 0..bsz {
            model.prefill(&histories[b], &mut batch_caches[b]);
            model.prefill(&histories[b], &mut solo_caches[b]);
        }
        for round in 0..3 {
            let next: Vec<u16> = (0..bsz).map(|_| rng.below(128) as u16).collect();
            let batched = model.decode_batch(&next, &mut batch_caches);
            for b in 0..bsz {
                let solo = model.decode_step(next[b], &mut solo_caches[b]);
                assert_eq!(
                    batched.row(b),
                    &solo[..],
                    "{label} round {round} row {b}: fused decode logits differ"
                );
                assert_caches_identical(label, &batch_caches[b], &solo_caches[b]);
            }
        }
    }
}

/// Paged-vs-flat bitwise oracle: the gathered pool rows must be the
/// flat cache's bytes, layer by layer.
fn assert_paged_matches_flat(label: &str, pool: &KvPool, paged: &PagedKvCache, flat: &KvCache) {
    assert_eq!(paged.len(), flat.len(), "{label}: position count");
    for (li, l) in flat.layers.iter().enumerate() {
        let (k, v) = pool.materialize(paged, li);
        assert_eq!(k, l.k, "{label}: layer {li} K payload");
        assert_eq!(v, l.v, "{label}: layer {li} V payload");
    }
}

#[test]
fn paged_cache_bit_identical_to_flat_all_backends() {
    // The tentpole contract: with quantization off, the block-paged
    // pool path (prefill_paged + decode_batch_paged, block boundaries
    // everywhere) produces the same logits AND the same K/V bytes as
    // the flat path, per backend lane with the real serving engines.
    let mut rng = Rng::new(5);
    for (label, cfg) in lanes() {
        let model = lane_model(&cfg);
        // Block size 3: prompts and contexts straddle blocks.
        let mut pool = model.new_pool(
            &PoolConfig { block_size: 3, budget_blocks: 64, quant: KvQuantConfig::off() },
            1,
        );
        let bsz = 3usize;
        let prompts: Vec<Vec<u16>> = (0..bsz)
            .map(|b| (0..2 * b + 3).map(|_| rng.below(128) as u16).collect())
            .collect();
        let mut flat: Vec<KvCache> = (0..bsz).map(|_| model.new_cache(32)).collect();
        let mut paged: Vec<PagedKvCache> = (0..bsz).map(|_| pool.new_cache()).collect();
        for b in 0..bsz {
            let lf = model.prefill(&prompts[b], &mut flat[b]);
            let lp = model.prefill_paged(&prompts[b], &mut paged[b], &mut pool);
            assert_eq!(lf, lp, "{label} request {b}: prefill logits differ");
        }
        for round in 0..4 {
            let next: Vec<u16> = (0..bsz).map(|_| rng.below(128) as u16).collect();
            let lf = model.decode_batch(&next, &mut flat);
            let lp = model.decode_batch_paged(&next, &mut paged, &mut pool);
            assert_eq!(
                lf.data, lp.data,
                "{label} round {round}: fused decode logits differ"
            );
            for b in 0..bsz {
                assert_paged_matches_flat(label, &pool, &paged[b], &flat[b]);
            }
        }
        for mut c in paged {
            pool.release(&mut c);
        }
        assert_eq!(pool.blocks_in_use(), 0, "{label}: pool drained");
    }
}

#[test]
fn quantized_kv_stays_close_and_actually_shrinks() {
    // With kv_bits=4 the paged outputs are no longer bit-identical —
    // but they must stay finite and close (cold rows carry <= half a
    // quantization step of error), and the pool must measurably
    // shrink versus its all-f32 footprint.
    let model = lane_model(&lanes()[0].1); // fp16 lane
    let quant = KvQuantConfig { bits: 4, local_window: 4 };
    let mut pool = model.new_pool(&PoolConfig { block_size: 4, budget_blocks: 64, quant }, 1);
    let mut fpool = model.new_pool(
        &PoolConfig { block_size: 4, budget_blocks: 64, quant: KvQuantConfig::off() },
        1,
    );
    let prompt: Vec<u16> = (0..16).map(|i| (i * 7 + 3) as u16).collect();
    let mut qc = pool.new_cache();
    let mut fc = fpool.new_cache();
    model.prefill_paged(&prompt, &mut qc, &mut pool);
    model.prefill_paged(&prompt, &mut fc, &mut fpool);
    pool.quantize_cold(&qc);
    let mut next_q = 1u16;
    let mut next_f = 1u16;
    for _ in 0..8 {
        let lq = model.decode_batch_paged(&[next_q], std::slice::from_mut(&mut qc), &mut pool);
        let lf = model.decode_batch_paged(&[next_f], std::slice::from_mut(&mut fc), &mut fpool);
        assert!(lq.data.iter().all(|v| v.is_finite()), "quantized decode stays finite");
        // Greedy tokens usually agree at int4 on this tiny model; we
        // only require the quantized run to keep producing valid
        // logits while following its own trajectory.
        next_q = argmax(lq.row(0));
        next_f = argmax(lf.row(0));
        pool.quantize_cold(&qc);
    }
    let qs = pool.stats();
    let fs = fpool.stats();
    assert!(qs.quant_blocks >= 3, "cold blocks quantized: {}", qs.quant_blocks);
    assert!(
        qs.resident_bytes * 2 < fs.resident_bytes,
        "int4 pool must be well under half the f32 pool: {} vs {}",
        qs.resident_bytes,
        fs.resident_bytes
    );
    pool.release(&mut qc);
    fpool.release(&mut fc);
}

/// A W1A8 model: same deterministic quantization as `lane_model`, but
/// every linear carries a scale-free 8-bit activation quantizer (the
/// serve `--act-bits 8` arming) before the engines are prepared, so
/// the packed lanes take the true integer path.
fn w1a8_model(cfg: &QuantConfig) -> Transformer {
    use btc_llm::quant::actquant::ActQuant;
    let (raw, corpus) = tiny_raw_model(21);
    let mut qm = quantize_model(&raw, &corpus, cfg).expect("quantize fixture");
    for b in qm.model.blocks.iter_mut() {
        for (_, lin) in b.linears_mut() {
            lin.act_quant = Some(ActQuant { bits: 8, scale: Vec::new() });
        }
    }
    qm.model.prepare_engines();
    qm.model
}

#[test]
fn w1a8_int_path_logits_within_bound_of_f32_reference() {
    // Accuracy contract of the integer compute path (DESIGN.md §12):
    // per backend lane, W1A8 logits stay within a documented relative
    // divergence of the f32 path over the same weights. The fp16 lane
    // has no packed engine, so its scale-free quantizer is a no-op and
    // the logits are bit-identical.
    use btc_llm::eval::error_stats::logit_divergence;
    let mut rng = Rng::new(9);
    for (label, cfg) in lanes() {
        let reference = lane_model(&cfg);
        let int_model = w1a8_model(&cfg);
        for trial in 0..3 {
            let len = 2 + rng.below(8);
            let prompt: Vec<u16> = (0..len).map(|_| rng.below(128) as u16).collect();
            let a = int_model.forward(&prompt);
            let r = reference.forward(&prompt);
            assert!(a.data.iter().all(|v| v.is_finite()), "{label} trial {trial}: finite");
            let d = logit_divergence(&a, &r);
            if label == "fp16" {
                assert_eq!(d.max_abs, 0.0, "{label} trial {trial}: dense path must be exact");
            } else {
                assert!(
                    d.rel < 0.08,
                    "{label} trial {trial}: rel divergence {:.5} (max_abs {:.5}, mean_abs {:.5})",
                    d.rel,
                    d.max_abs,
                    d.mean_abs
                );
            }
        }
    }
}

#[test]
fn w1a8_perplexity_within_bound_of_f32_reference() {
    // The end-to-end accuracy gate: on the hermetic corpus, W1A8
    // perplexity stays within 15% of the f32 sim-quant path, per lane
    // (the bound documented in DESIGN.md §12; fp16 is exact).
    use btc_llm::eval::perplexity::perplexity;
    let (_, corpus) = tiny_raw_model(21);
    let tokens: Vec<u16> = corpus.iter().map(|&b| (b as u16) % 128).collect();
    for (label, cfg) in lanes() {
        let reference = lane_model(&cfg);
        let int_model = w1a8_model(&cfg);
        let ppl_f = perplexity(&reference, &tokens, 16, 192);
        let ppl_i = perplexity(&int_model, &tokens, 16, 192);
        assert!(ppl_i.is_finite() && ppl_i > 1.0, "{label}: ppl {ppl_i}");
        let rel = (ppl_i / ppl_f - 1.0).abs();
        if label == "fp16" {
            assert_eq!(ppl_i.to_bits(), ppl_f.to_bits(), "{label}: dense path must be exact");
        } else {
            assert!(rel < 0.15, "{label}: W1A8 ppl {ppl_i} vs f32 {ppl_f} ({:.1}% off)", rel * 100.0);
        }
    }
}

fn argmax(xs: &[f32]) -> u16 {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as u16)
        .unwrap_or(0)
}

#[test]
fn packed_engine_forward_equals_dense_reconstruction_all_backends() {
    // Packed-vs-unpacked equivalence: the prepared serving engines
    // (sign-GEMM over BitMatrix, LUT-GEMM over the packed block-major
    // index plane) must agree with a dense reconstruction of the SAME
    // backends (cache_dense_all unpacks every packed plane to f32 and
    // runs plain GEMMs). Quantization is deterministic per seed, so
    // the two models hold identical weights.
    use btc_llm::util::proptest::assert_close;
    let mut rng = Rng::new(7);
    for (label, cfg) in lanes() {
        let (raw, corpus) = tiny_raw_model(33);
        let mut packed = quantize_model(&raw, &corpus, &cfg).expect("quantize fixture").model;
        packed.prepare_engines();
        let mut dense = quantize_model(&raw, &corpus, &cfg).expect("quantize fixture").model;
        dense.cache_dense_all();
        for trial in 0..3 {
            let len = 1 + rng.below(8);
            let prompt: Vec<u16> = (0..len).map(|_| rng.below(128) as u16).collect();
            let a = packed.forward(&prompt);
            let b = dense.forward(&prompt);
            assert_close(&a.data, &b.data, 1e-3, 1e-3)
                .unwrap_or_else(|e| panic!("{label} trial {trial}: {e}"));
        }
    }
}

#[test]
fn btc_resident_bytes_track_accounted_bits() {
    // The codebook lane's packed storage: measured resident bytes of
    // every codebook linear stay close to the accounted storage_bits
    // (per-row word alignment is the only slack; at this tiny d=16
    // fixture it is the worst case, so the bound is generous — the
    // release memory bench pins <= 5% at a realistic shape).
    let model = lane_model(&lanes().pop().expect("btc lane").1);
    let mut saw_codebook = false;
    for block in &model.blocks {
        for (name, lin) in block.linears() {
            if lin.backend.tag() != "codebook" {
                continue;
            }
            saw_codebook = true;
            let accounted = lin.backend.storage_bits().div_ceil(8);
            let resident = lin.backend.resident_bytes();
            assert!(
                resident < 3 * accounted,
                "{name}: resident {resident} vs accounted {accounted}"
            );
        }
    }
    assert!(saw_codebook, "btc lane produced no codebook linears");
}
