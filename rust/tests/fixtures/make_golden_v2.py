#!/usr/bin/env python3
"""Regenerate qlm_v2_codebook.qlm — a hand-assembled QLM1 **v2**
container (the pre-packed-plane layout: u64 codebook words, dense u32
centroid indices, f32 scales) targeting the hermetic tiny fixture model
(vocab 128, d_model 16, 2 layers).

The committed bytes are a golden back-compat fixture: the Rust loader
must keep reading them bit-identically after any future container
bump. Values are chosen to be exactly representable in f16 so the
load-time f32->f16 scale rounding is lossless and the Rust test can
compare exactly.

Run from anywhere: python3 rust/tests/fixtures/make_golden_v2.py
"""

import os
import struct

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "qlm_v2_codebook.qlm")

# Tiny fixture model config (util::fixture::tiny_raw_model).
VOCAB, D_MODEL, N_LAYER, N_HEAD, N_KV_HEAD, D_FF, MAX_SEQ = 128, 16, 2, 2, 2, 24, 64
ROPE_THETA = 10000.0

# Shared codebook: v=8, c=4.
V, C = 8, 4
WORDS = [0x00, 0xFF, 0x0F, 0x3C]

# One codebook linear: layer 0, slot 0 (wq, 16x16) -> 2 blocks/row.
ROWS, COLS, N_GROUPS = 16, 16, 1
IDX = [(i * 7) % C for i in range(ROWS * (COLS // V))]
ALPHA = [0.5 + (i % 8) * 0.25 for i in range(ROWS)]
MU = [(i % 4) * 0.125 - 0.25 for i in range(ROWS)]
COL_GROUP = [0] * COLS


def main():
    b = bytearray()
    b += b"QLM1"
    b += struct.pack("<I", 2)  # version 2
    for x in (VOCAB, D_MODEL, N_LAYER, N_HEAD, N_KV_HEAD, D_FF, MAX_SEQ):
        b += struct.pack("<I", x)
    b += struct.pack("<f", ROPE_THETA)
    # Shared codebook header (v2: one u64 per centroid).
    b += struct.pack("<B", 1)
    b += struct.pack("<II", V, C)
    for w in WORDS:
        b += struct.pack("<Q", w)
    # One linear record.
    b += struct.pack("<I", 1)
    b += struct.pack("<I", 0)  # layer 0
    b += struct.pack("<B", 0)  # slot wq
    tag = b"codebook"
    b += struct.pack("<B", len(tag)) + tag
    b += struct.pack("<B", 0)  # no transform
    b += struct.pack("<B", 0)  # no act-quant
    # v2 codebook payload: dims, dense u32 idx, f32 scales, u16 groups.
    b += struct.pack("<III", ROWS, COLS, N_GROUPS)
    for k in IDX:
        b += struct.pack("<I", k)
    for a in ALPHA:
        b += struct.pack("<f", a)
    for m in MU:
        b += struct.pack("<f", m)
    for g in COL_GROUP:
        b += struct.pack("<H", g)
    with open(OUT, "wb") as f:
        f.write(bytes(b))
    print(f"wrote {OUT} ({len(b)} bytes)")


if __name__ == "__main__":
    main()
