//! Forced-variant SIMD equivalence suite (DESIGN.md §11): every
//! dispatchable kernel lane is driven across awkward shapes at every
//! level the host supports, against the scalar oracle.
//!
//! Contract being pinned:
//! - integer kernels (XOR+POPCNT Hamming) and the LUT-GEMM gather are
//!   **bit-identical** across levels (and, for the gather, across
//!   every tile width);
//! - the FMA dot lane and the sign-GEMM masked accumulate reassociate,
//!   so they are **ULP-bounded** against an f64 reference, with the
//!   bound asserted (not just "close");
//! - `Level::Scalar` is bitwise the historical pre-SIMD code path.
//!
//! - the i8×sign integer lanes (`forward_i8`) are **bit-identical**
//!   across levels: integer addition is exactly associative, so any
//!   vectorization order yields the same i32 accumulator, and the f32
//!   epilogue is evaluated in one fixed order.
//!
//! Everything here pins the level through explicit `EngineCtx`
//! constructors — the process-global dispatch level is never mutated,
//! so this suite is race-free under the parallel test harness.

use btc_llm::bitops::hamming::{hamming_words_padded_with_level, hamming_words_with_level};
use btc_llm::bitops::pack::pack_signs;
use btc_llm::engine::lutgemm::{GATHER_TILE_DEFAULT, GATHER_TILE_MAX};
use btc_llm::engine::{BinaryGemmEngine, EngineCtx, LutGemmEngine, QuantizedActs};
use btc_llm::quant::arb::arb_quantize;
use btc_llm::quant::binarize::BinaryLayer;
use btc_llm::quant::codebook::{collect_vectors, BinaryCodebook, CodebookLayer};
use btc_llm::tensor::matrix::{dot_scalar, dot_with_level};
use btc_llm::tensor::Matrix;
use btc_llm::util::rng::Rng;
use btc_llm::util::simd::{self, Level};
use std::sync::Arc;

/// Shapes chosen to hit every tail path: single partial word
/// (cols % 64 == 1 and == 63), exact word multiples, multi-word rows.
const AWKWARD_COLS: &[usize] = &[1, 63, 64, 65, 127, 128, 193, 512];

fn sign_vec(r: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| r.sign()).collect()
}

fn bin_eng(layer: &BinaryLayer, l: Level) -> BinaryGemmEngine {
    BinaryGemmEngine::with_ctx(layer, &EngineCtx::current().with_level(l))
}

fn lut_eng(layer: &CodebookLayer, l: Level, tile: usize) -> Option<LutGemmEngine> {
    LutGemmEngine::try_with_ctx(layer, &EngineCtx::current().with_level(l).with_gather_tile(tile))
}

#[test]
fn popcount_lanes_bit_identical_on_awkward_widths() {
    let mut r = Rng::new(0xD15);
    for &n in AWKWARD_COLS {
        let a = sign_vec(&mut r, n);
        let b = sign_vec(&mut r, n);
        let pa = pack_signs(&a);
        let pb = pack_signs(&b);
        let mask = if n % 64 == 0 { u64::MAX } else { (1u64 << (n % 64)) - 1 };
        let want = hamming_words_with_level(Level::Scalar, &pa, &pb, mask);
        let want_pad = hamming_words_padded_with_level(Level::Scalar, &pa, &pb);
        assert_eq!(want, want_pad, "clean padding: both tail policies agree (n={n})");
        for l in simd::supported_levels() {
            assert_eq!(hamming_words_with_level(l, &pa, &pb, mask), want, "n={n} {l:?}");
            assert_eq!(hamming_words_padded_with_level(l, &pa, &pb), want_pad, "n={n} {l:?}");
        }
    }
}

#[test]
fn dot_lanes_ulp_bounded_and_scalar_is_oracle() {
    let mut r = Rng::new(0xD07);
    for &n in AWKWARD_COLS {
        let a: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let exact: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        let mag: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
        // Worst-case relative rounding growth of an n-term f32 sum is
        // O(n·eps)·Σ|terms|; factor 4 covers the lane reductions.
        let bound = 4.0 * n.max(1) as f64 * f32::EPSILON as f64 * mag + 1e-30;
        for l in simd::supported_levels() {
            let got = dot_with_level(l, &a, &b) as f64;
            assert!(
                (got - exact).abs() <= bound,
                "dot n={n} {l:?}: |{got} - {exact}| > {bound}"
            );
        }
        // The Scalar level IS the historical unroll, bit for bit.
        let s = dot_with_level(Level::Scalar, &a, &b);
        assert_eq!(s.to_bits(), dot_scalar(&a, &b).to_bits(), "n={n}");
    }
}

/// f64 reference for the sign-GEMM (the reconstructed weight
/// `w̃ = alpha·(±1) + mu` already carries the scales): per output,
/// the exact f64 sum and the magnitude sum Σ|x·w̃| for the bound.
fn sign_gemm_f64(layer: &BinaryLayer, x: &Matrix) -> (Vec<f64>, Vec<f64>) {
    let w = layer.reconstruct();
    let mut exact = vec![0f64; x.rows * w.rows];
    let mut mags = vec![0f64; x.rows * w.rows];
    for i in 0..x.rows {
        for rr in 0..w.rows {
            let (mut s, mut m) = (0f64, 0f64);
            for c in 0..w.cols {
                let t = x.at(i, c) as f64 * w.at(rr, c) as f64;
                s += t;
                m += t.abs();
            }
            exact[i * w.rows + rr] = s;
            mags[i * w.rows + rr] = m;
        }
    }
    (exact, mags)
}

#[test]
fn sign_gemm_lanes_ulp_bounded_vs_f64_reference() {
    let mut rng = Rng::new(0x51611);
    // cols % 64 == 1 and == 63 exercise the masked-accumulate tail.
    for &(rows, cols) in &[(24usize, 193usize), (16, 127), (8, 64)] {
        let w = Matrix::randn(rows, cols, &mut rng);
        let q = BinaryLayer::quantize(&w);
        let x = Matrix::randn(3, cols, &mut rng);
        let (exact, mags) = sign_gemm_f64(&q, &x);
        for l in simd::supported_levels() {
            let eng = bin_eng(&q, l);
            let y = eng.forward(&x);
            for (i, (&got, (&want, &mag))) in
                y.data.iter().zip(exact.iter().zip(&mags)).enumerate()
            {
                // The engine computes alpha·(2·pos − Σx) + mu·Σx: three
                // O(cols)-term f32 sums, each with worst-case error
                // O(cols·eps)·Σ|terms|; factor 8 covers the
                // rearrangement slack across the lanes.
                let bound = 8.0 * cols as f64 * f32::EPSILON as f64 * mag + 1e-20;
                assert!(
                    (got as f64 - want).abs() <= bound,
                    "{rows}x{cols} {l:?} out[{i}]: {got} vs f64 {want} (bound {bound})"
                );
            }
        }
    }
}

#[test]
fn grouped_sign_gemm_with_empty_group_every_lane() {
    // Declared 4 groups, only {0, 2} used — group 1 and 3 are empty
    // masks; every lane must agree with the dequant reference and the
    // scalar-lane engine must match historical outputs bitwise.
    let mut rng = Rng::new(0x6E0);
    let cols = 96usize;
    let w = Matrix::randn(12, cols, &mut rng);
    let groups: Vec<u16> = (0..cols).map(|c| if c < 48 { 0 } else { 2 }).collect();
    let q = arb_quantize(&w, &groups, 4, 3);
    let x = Matrix::randn(2, cols, &mut rng);
    let wd = q.reconstruct();
    let oracle = bin_eng(&q, Level::Scalar).forward(&x);
    for l in simd::supported_levels() {
        let y = bin_eng(&q, l).forward(&x);
        for i in 0..x.rows {
            for rr in 0..w.rows {
                let want: f64 = (0..cols)
                    .map(|c| x.at(i, c) as f64 * wd.at(rr, c) as f64)
                    .sum();
                let got = y.at(i, rr) as f64;
                assert!(
                    (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                    "{l:?} y[{i},{rr}] = {got}, dequant {want}"
                );
            }
        }
        if l == Level::Scalar {
            assert_eq!(y.data, oracle.data);
        }
    }
}

fn codebook_layer(rng: &mut Rng, rows: usize, cols: usize, v: usize, c: usize) -> CodebookLayer {
    let w = Matrix::randn(rows, cols, rng);
    let bl = BinaryLayer::quantize(&w);
    let vectors = collect_vectors(&bl, v);
    let (cb, assign, _) = BinaryCodebook::build(&vectors, v, c, 3);
    CodebookLayer::from_assignments(&bl, Arc::new(cb), assign)
}

#[test]
fn lut_gather_bit_identical_across_levels_and_tiles() {
    let mut rng = Rng::new(0x107);
    // (out < tile), ragged cols (21 = 2·8 + 5), and a tall layer that
    // spans several tiles.
    let shapes = [(5usize, 21usize, 8usize, 16usize), (70, 64, 16, 40), (130, 48, 8, 64)];
    for &(rows, cols, v, c) in &shapes {
        let cl = codebook_layer(&mut rng, rows, cols, v, c);
        let x = Matrix::randn(2, cols, &mut rng);
        let oracle = lut_eng(&cl, Level::Scalar, GATHER_TILE_DEFAULT)
            .expect("block aligned")
            .forward(&x);
        for l in simd::supported_levels() {
            for tile in [1usize, 3, GATHER_TILE_DEFAULT, GATHER_TILE_MAX] {
                let y = lut_eng(&cl, l, tile).unwrap().forward(&x);
                assert_eq!(y.data, oracle.data, "{rows}x{cols} v={v} {l:?} tile={tile}");
            }
        }
    }
}

#[test]
fn grouped_lut_gather_bit_identical_with_empty_group() {
    // Block-aligned groups {0, 2} of a declared 4 (two empty groups),
    // driven through every lane × tile width.
    let mut rng = Rng::new(0x1D8);
    let cols = 32usize;
    let w = Matrix::randn(40, cols, &mut rng);
    let groups: Vec<u16> = (0..cols).map(|c| if c < 16 { 0 } else { 2 }).collect();
    let bl = arb_quantize(&w, &groups, 4, 3);
    let vectors = collect_vectors(&bl, 8);
    let (cb, assign, _) = BinaryCodebook::build(&vectors, 8, 12, 3);
    let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
    let x = Matrix::randn(1, cols, &mut rng);
    let oracle = lut_eng(&cl, Level::Scalar, GATHER_TILE_DEFAULT)
        .expect("block aligned")
        .forward(&x);
    for l in simd::supported_levels() {
        for tile in [1usize, 5, GATHER_TILE_MAX] {
            let y = lut_eng(&cl, l, tile).unwrap().forward(&x);
            assert_eq!(y.data, oracle.data, "{l:?} tile={tile}");
        }
    }
}

#[test]
fn sign_gemm_i8_lanes_bit_identical_vs_scalar_oracle() {
    // Integer activations: cols % 64 == 1 and == 63 exercise the
    // partial final bit-word; 193 spans several words. Every lane must
    // reproduce the scalar i32 walk bit for bit.
    let mut rng = Rng::new(0x18A8);
    for &(rows, cols) in &[(9usize, 1usize), (16, 63), (24, 193)] {
        let w = Matrix::randn(rows, cols, &mut rng);
        let q = BinaryLayer::quantize(&w);
        let x = Matrix::randn(3, cols, &mut rng);
        let qa = QuantizedActs::quantize(&x, 8);
        let oracle = bin_eng(&q, Level::Scalar).forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        for l in simd::supported_levels() {
            let y = bin_eng(&q, l).forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
            assert_eq!(y.data, oracle.data, "{rows}x{cols} {l:?}");
        }
    }
}

#[test]
fn grouped_sign_gemm_i8_bit_identical_with_empty_group() {
    // Same empty-group layout as the f32 test above, through the
    // integer path: per-group i32 sums, alpha applied in the epilogue.
    let mut rng = Rng::new(0x6E8);
    let cols = 96usize;
    let w = Matrix::randn(12, cols, &mut rng);
    let groups: Vec<u16> = (0..cols).map(|c| if c < 48 { 0 } else { 2 }).collect();
    let q = arb_quantize(&w, &groups, 4, 3);
    let x = Matrix::randn(2, cols, &mut rng);
    let qa = QuantizedActs::quantize(&x, 8);
    let oracle = bin_eng(&q, Level::Scalar).forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
    for l in simd::supported_levels() {
        let y = bin_eng(&q, l).forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        assert_eq!(y.data, oracle.data, "{l:?}");
    }
}

#[test]
fn sign_gemm_i8_empty_rows_every_lane() {
    let mut rng = Rng::new(0x0E0);
    let w = Matrix::randn(6, 65, &mut rng);
    let q = BinaryLayer::quantize(&w);
    let qa = QuantizedActs::quantize(&Matrix::zeros(0, 65), 8);
    for l in simd::supported_levels() {
        let y = bin_eng(&q, l).forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        assert_eq!((y.rows, y.cols), (0, 6), "{l:?}");
        assert!(y.data.is_empty(), "{l:?}");
    }
}

#[test]
fn lut_gather_i8_bit_identical_across_levels_and_tiles() {
    // Same shape sweep as the f32 gather test, with int8 activations:
    // the i32 Stage-I/Stage-II tables and the gather accumulate are
    // exact, so every level × tile combination is bit-identical.
    let mut rng = Rng::new(0x1A7);
    let shapes = [(5usize, 21usize, 8usize, 16usize), (70, 64, 16, 40), (130, 48, 8, 64)];
    for &(rows, cols, v, c) in &shapes {
        let cl = codebook_layer(&mut rng, rows, cols, v, c);
        let x = Matrix::randn(2, cols, &mut rng);
        let qa = QuantizedActs::quantize(&x, 8);
        let oracle = lut_eng(&cl, Level::Scalar, GATHER_TILE_DEFAULT)
            .expect("block aligned")
            .forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        for l in simd::supported_levels() {
            for tile in [1usize, 3, GATHER_TILE_DEFAULT, GATHER_TILE_MAX] {
                let y =
                    lut_eng(&cl, l, tile).unwrap().forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
                assert_eq!(y.data, oracle.data, "{rows}x{cols} v={v} {l:?} tile={tile}");
            }
        }
    }
}

#[test]
fn lut_gather_i8_empty_rows_every_lane() {
    let mut rng = Rng::new(0x1E0);
    let cl = codebook_layer(&mut rng, 10, 24, 8, 12);
    let qa = QuantizedActs::quantize(&Matrix::zeros(0, 24), 8);
    for l in simd::supported_levels() {
        let y = lut_eng(&cl, l, GATHER_TILE_DEFAULT)
            .unwrap()
            .forward_i8(&qa.q, &qa.scales, qa.rows, qa.cols);
        assert_eq!((y.rows, y.cols), (0, 10), "{l:?}");
        assert!(y.data.is_empty(), "{l:?}");
    }
}

#[test]
fn matmul_bt_agrees_with_scalar_dot_within_bound() {
    // The full GEMM through whatever lane is globally active must stay
    // ULP-bounded against the scalar dot applied row by row.
    let mut rng = Rng::new(0xABC);
    let a = Matrix::randn(4, 193, &mut rng);
    let b = Matrix::randn(9, 193, &mut rng);
    let y = a.matmul_bt(&b);
    for i in 0..a.rows {
        for j in 0..b.rows {
            let exact: f64 = a
                .row(i)
                .iter()
                .zip(b.row(j))
                .map(|(&x, &w)| x as f64 * w as f64)
                .sum();
            let mag: f64 = a
                .row(i)
                .iter()
                .zip(b.row(j))
                .map(|(&x, &w)| (x as f64 * w as f64).abs())
                .sum();
            let bound = 4.0 * 193.0 * f32::EPSILON as f64 * mag + 1e-30;
            assert!(
                (y.at(i, j) as f64 - exact).abs() <= bound,
                "y[{i},{j}] = {} vs {exact}",
                y.at(i, j)
            );
        }
    }
}
