//! HLO parity: load the AOT artifacts (JAX/Pallas graphs lowered to
//! HLO text by `python/compile/aot.py`) through the PJRT runtime and
//! check the Rust engines reproduce their numerics exactly.
//!
//! Three cross-checks, covering all three layers:
//!  1. `binary_gemm.hlo.txt` (L1 Pallas W1A16 kernel)  == engine::xnor
//!  2. `lut_gemm.hlo.txt`    (L1 Pallas LUT-GEMM)      == engine::lutgemm
//!  3. `tinylm_s_fwd.hlo.txt` (full L2 model forward)  == model::Transformer
//!
//! ```bash
//! cargo run --release --example hlo_parity
//! ```

use std::sync::Arc;

use btc_llm::bitops::BitMatrix;
use btc_llm::engine::{BinaryGemmEngine, EngineCtx, LutGemmEngine};
use btc_llm::io::load_model;
use btc_llm::model::Transformer;
use btc_llm::quant::binarize::BinaryLayer;
use btc_llm::quant::codebook::{BinaryCodebook, CodebookLayer};
use btc_llm::runtime::{PjrtRuntime, TensorArg};
use btc_llm::tensor::Matrix;
use btc_llm::util::f16;
use btc_llm::util::proptest::assert_close;
use btc_llm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = btc_llm::artifacts_dir();
    let mut rt = PjrtRuntime::cpu(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let mut rng = Rng::new(42);

    // ---- 1. binary_gemm kernel (m=8, n=96, o=64; shapes fixed at AOT) --
    let (m, n, o) = (8usize, 96usize, 64usize);
    let x = Matrix::randn(m, n, &mut rng);
    let bsigns: Vec<f32> = (0..o * n).map(|_| rng.sign()).collect();
    let alpha: Vec<f32> = (0..o).map(|_| rng.range_f32(0.2, 2.0)).collect();
    let mu: Vec<f32> = (0..o).map(|_| rng.normal() * 0.1).collect();
    let jax_out = rt.run_f32(
        "binary_gemm.hlo.txt",
        &[
            TensorArg::F32(vec![m, n], x.data.clone()),
            TensorArg::F32(vec![o, n], bsigns.clone()),
            TensorArg::F32(vec![o], alpha.clone()),
            TensorArg::F32(vec![o], mu.clone()),
        ],
    )?;
    let layer = BinaryLayer {
        rows: o,
        cols: n,
        b: BitMatrix::from_signs(o, n, &bsigns),
        alpha: alpha.clone(),
        mu: mu.clone(),
        col_group: vec![0; n],
        n_groups: 1,
    };
    let rust_out = BinaryGemmEngine::with_ctx(&layer, &EngineCtx::current()).forward(&x);
    assert_close(&rust_out.data, &jax_out, 1e-3, 1e-3)
        .map_err(|e| anyhow::anyhow!("binary_gemm parity: {e}"))?;
    println!("1. binary_gemm: Pallas/PJRT == engine::xnor  ({} outputs) ✓", jax_out.len());

    // ---- 2. lut_gemm kernel (c=32, v=16, same x) ------------------------
    let (c, v) = (32usize, 16usize);
    let cb_signs: Vec<f32> = (0..c * v).map(|_| rng.sign()).collect();
    let nb = n / v;
    let idx: Vec<i32> = (0..o * nb).map(|_| rng.below(c) as i32).collect();
    // CodebookLayer rounds its scales to f16 (the shipping precision),
    // so feed the JAX kernel the same rounded values to keep the
    // comparison apples-to-apples.
    let alpha16 = f16::decode_vec(&f16::encode_vec(&alpha));
    let mu16 = f16::decode_vec(&f16::encode_vec(&mu));
    let jax_out = rt.run_f32(
        "lut_gemm.hlo.txt",
        &[
            TensorArg::F32(vec![m, n], x.data.clone()),
            TensorArg::F32(vec![c, v], cb_signs.clone()),
            TensorArg::I32(vec![o, nb], idx.clone()),
            TensorArg::F32(vec![o], alpha16.clone()),
            TensorArg::F32(vec![o], mu16.clone()),
        ],
    )?;
    let cb_words: Vec<u64> = (0..c)
        .map(|k| btc_llm::bitops::pack::pack_signs(&cb_signs[k * v..(k + 1) * v])[0])
        .collect();
    let codebook = Arc::new(BinaryCodebook { v, words: cb_words });
    let idx_u32: Vec<u32> = idx.iter().map(|&i| i as u32).collect();
    let ungrouped = vec![0u16; n];
    let cl = CodebookLayer::new(o, n, codebook, &idx_u32, &alpha16, &mu16, &ungrouped, 1);
    let rust_out = LutGemmEngine::try_with_ctx(&cl, &EngineCtx::current()).unwrap().forward(&x);
    assert_close(&rust_out.data, &jax_out, 1e-3, 1e-3)
        .map_err(|e| anyhow::anyhow!("lut_gemm parity: {e}"))?;
    println!("2. lut_gemm:    Pallas/PJRT == engine::lutgemm ({} outputs) ✓", jax_out.len());

    // ---- 3. full model forward (tokens + weights in sorted order) -------
    let seq = 32usize;
    let tokens: Vec<u16> = (0..seq).map(|i| (40 + (i * 7) % 60) as u16).collect();
    let raw = load_model(&dir.join("tinylm_s.bin"))?;
    let mut fwd_args =
        vec![TensorArg::I32(vec![1, seq], tokens.iter().map(|&t| t as i32).collect())];
    for (_, (dims, data)) in raw.tensors.iter() {
        // BTreeMap iterates name-sorted — the AOT calling convention.
        fwd_args.push(TensorArg::F32(dims.clone(), data.clone()));
    }
    let jax_logits = rt.run_f32("tinylm_s_fwd.hlo.txt", &fwd_args)?;
    let model = Transformer::from_raw(&raw)?;
    let rust_logits = model.forward(&tokens);
    assert_close(&rust_logits.data, &jax_logits, 5e-2, 5e-3)
        .map_err(|e| anyhow::anyhow!("model forward parity: {e}"))?;
    // Also check argmax agreement at every position (the decisions).
    let vocab = raw.config.vocab;
    for pos in 0..seq {
        let r = &rust_logits.data[pos * vocab..(pos + 1) * vocab];
        let j = &jax_logits[pos * vocab..(pos + 1) * vocab];
        let am = |xs: &[f32]| {
            xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        assert_eq!(am(r), am(j), "argmax mismatch at pos {pos}");
    }
    println!("3. tinylm_s_fwd: JAX/PJRT == model::Transformer ({} logits, argmax exact) ✓", jax_logits.len());
    println!("\nhlo_parity OK — all three layers compose.");
    Ok(())
}
