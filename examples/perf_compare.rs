//! CI perf-regression gate: compare the current run's `BENCH_*.json`
//! against the committed snapshots in `benches/baseline/`, fail (exit
//! 1) when a gated metric regresses beyond tolerance, and write a
//! markdown delta table (to `--summary` and, when set, to the file
//! named by `$GITHUB_STEP_SUMMARY`) so every PR shows its point on the
//! perf trajectory.
//!
//! ```bash
//! BENCH_JSON=1 cargo bench --bench bench_serve_e2e -- --quick   # emit BENCH_serve.json
//! cargo run --release --example perf_compare -- \
//!     --baseline benches/baseline --current . --threshold 30
//! # refresh the committed baseline from the current run:
//! cargo run --release --example perf_compare -- --write-baseline
//! ```
//!
//! Missing files are handled gracefully: no baseline snapshot means
//! "recording only" (exit 0) so the gate can be introduced before the
//! first baseline lands; a missing current file just skips that
//! experiment. See benches/baseline/README.md for the refresh
//! protocol.

use std::path::Path;

use btc_llm::util::argparse::Args;
use btc_llm::util::benchkit::{compare_reports, parse_report, Gate};

/// The gated experiments: row-identity keys + per-metric gates.
/// Latency-shaped metrics get the (noisy-CI-runner) default
/// tolerance; the memory experiment is deterministic, so its gates
/// are tight regardless of `--threshold`.
fn spec_for(exp: &str, pct: f64) -> (Vec<&'static str>, Vec<Gate>) {
    match exp {
        "serve" => (
            // `policy`/`tenant` only exist on adversarial-scenario
            // rows and `spec` (on/off) only on spec-scenario rows;
            // elsewhere they render as "-" and stay inert in the row
            // key.
            vec!["scenario", "backend", "batch", "policy", "tenant", "spec", "workload"],
            vec![
                Gate::higher("tokens_per_s", pct),
                Gate::lower("p50_ms", pct),
                Gate::lower("ttft_p50_ms", pct),
                Gate::lower("itl_p50_ms", pct),
                Gate::lower("ttft_p95_ms", pct),
                Gate::lower("itl_p95_ms", pct),
                // Spec-scenario rows (also `decode_us_per_tok` on the
                // batch sweep): rows lacking a gated metric are
                // skipped, so these stay inert elsewhere.
                Gate::lower("decode_us_per_tok", pct),
                Gate::higher("accepted_per_round", pct),
                Gate::higher("spec_speedup_m1", pct),
            ],
        ),
        "fig5" => (
            vec!["m", "threads"],
            vec![
                Gate::lower("fp_ms", pct),
                Gate::lower("sign_ms", pct),
                Gate::lower("lut_ms", pct),
            ],
        ),
        "memory" => (
            vec![],
            vec![
                Gate::lower("resident_bits_per_weight", 1.0),
                Gate::lower("accounted_bits_per_weight", 1.0),
                Gate::lower("file_bytes", 1.0),
            ],
        ),
        _ => (vec![], vec![]),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let baseline_dir = args.get_or("baseline", "benches/baseline").to_string();
    let current_dir = args.get_or("current", ".").to_string();
    let threshold = args.get_f64("threshold", 30.0);
    let write_baseline = args.flag("write-baseline");

    let mut md = String::from("## Perf trajectory vs committed baseline\n\n");
    let mut regressions = 0usize;
    let mut missing_rows = 0usize;
    let mut compared = 0usize;

    for exp in ["serve", "fig5", "memory"] {
        let cur_path = Path::new(&current_dir).join(format!("BENCH_{exp}.json"));
        let base_path = Path::new(&baseline_dir).join(format!("BENCH_{exp}.json"));
        let Ok(cur_text) = std::fs::read_to_string(&cur_path) else {
            md.push_str(&format!(
                "- `{exp}`: no current run ({}) — skipped\n",
                cur_path.display()
            ));
            continue;
        };
        if write_baseline {
            std::fs::create_dir_all(&baseline_dir)?;
            std::fs::write(&base_path, &cur_text)?;
            md.push_str(&format!("- `{exp}`: baseline refreshed → {}\n", base_path.display()));
            continue;
        }
        let Ok(base_text) = std::fs::read_to_string(&base_path) else {
            md.push_str(&format!(
                "- `{exp}`: no baseline snapshot ({}) — recording only; see \
                 benches/baseline/README.md\n",
                base_path.display()
            ));
            continue;
        };
        let cur = parse_report(&cur_text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", cur_path.display()))?;
        let base = parse_report(&base_text)
            .map_err(|e| anyhow::anyhow!("{}: {e}", base_path.display()))?;
        let (keys, gates) = spec_for(exp, threshold);
        let out = compare_reports(&base, &cur, &keys, &gates);
        regressions += out.regressions();
        // A baseline row with no current counterpart means the gate
        // silently stopped covering that scenario (renamed label,
        // changed runner shape, dropped grid point) — fail loudly and
        // force a baseline refresh rather than gating fiction.
        missing_rows += out.only_in_baseline.len();
        compared += out.deltas.len();
        md.push_str(&out.markdown(exp));
        md.push('\n');
    }

    md.push_str(&format!(
        "\n**{compared} gated metrics compared, {regressions} regression(s), \
         {missing_rows} baseline row(s) with no current match** (tolerance {threshold}%)\n"
    ));
    println!("{md}");

    if let Some(path) = args.get("summary") {
        std::fs::write(path, &md)?;
    }
    // GitHub Actions step summary: append, don't clobber other steps.
    if let Ok(path) = std::env::var("GITHUB_STEP_SUMMARY") {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(md.as_bytes())?;
    }

    if regressions > 0 || missing_rows > 0 {
        eprintln!(
            "perf gate FAILED: {regressions} gated metric(s) regressed > tolerance, \
             {missing_rows} baseline row(s) unmatched (refresh benches/baseline if the \
             grid/runner changed — see benches/baseline/README.md)"
        );
        std::process::exit(1);
    }
    Ok(())
}
