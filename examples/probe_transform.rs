use btc_llm::*;
use btc_llm::quant::transform::{fit, FitConfig};
use btc_llm::model::transformer::{Capture, CaptureSite};
fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let raw = io::load_model(&dir.join("tinylm_s.bin"))?;
    let model = model::Transformer::from_raw(&raw)?;
    let corpus = std::fs::read(dir.join("corpus_eval.txt"))?;
    let calib = data::calib::CalibSet::sample(&corpus, 8, 64, 42);
    let mut cap = Capture::new(192);
    for s in &calib.seqs { let mut o = Some(&mut cap); model.forward_capture(s, &mut o); }
    let x = cap.matrix(0, CaptureSite::Ln1Out).unwrap();
    let wq = raw.matrix("l0.wq")?; let wk = raw.matrix("l0.wk")?; let wv = raw.matrix("l0.wv")?;
    for (name, cfg) in [
        ("default", FitConfig::default()),
        ("more", FitConfig { outer_iters: 12, p_steps: 10, lr: 3e-2, ..Default::default() }),
        ("p-only", FitConfig { learn_sigma: false, ..Default::default() }),
        ("sigma-only", FitConfig { learn_p: false, ..Default::default() }),
    ] {
        let (_, st) = fit(&x, &[&wq, &wk, &wv], &cfg);
        println!("{name}: init {:.1} final {:.1} ratio {:.3} flips {} iters {}",
            st.initial_loss, st.final_loss, st.final_loss/st.initial_loss, st.sigma_flips, st.outer_iters_run);
    }
    Ok(())
}
