//! A third-party quantization method in ONE file: defines a toy
//! method ("mean-sign": per-row mean-magnitude scale, sign bits kept
//! as raw bytes) with its own `Quantizer` strategy and `WeightBackend`
//! storage format, registers both, and runs it end-to-end:
//!
//!   quantize (by registry name) → QLM1 serialize → reload → serve
//!
//! Nothing in the pipeline, model, container, or server knows this
//! method exists — that is the point of the trait/registry redesign.
//!
//! ```bash
//! cargo run --release --example custom_method
//! ```

use std::io::{Read, Write};
use std::time::Duration;

use anyhow::Result;
use btc_llm::coordinator::Server;
use btc_llm::data::ByteTokenizer;
use btc_llm::io::{qweights, wire};
use btc_llm::model::{register_backend, BackendIoCtx, Transformer, WeightBackend};
use btc_llm::quant::registry::{self, MethodEntry};
use btc_llm::quant::{QuantConfig, QuantOutcome, Quantizer, SiteId};
use btc_llm::tensor::Matrix;
use btc_llm::util::fixture::tiny_raw_model;

// ---- 1. the storage format ------------------------------------------

/// Per-row scale + one sign byte per weight (deliberately naive; a
/// real backend would bit-pack).
#[derive(Debug, Clone)]
struct MeanSign {
    rows: usize,
    cols: usize,
    alpha: Vec<f32>,
    signs: Vec<u8>, // 1 = +1, 0 = -1
}

impl MeanSign {
    fn quantize(w: &Matrix) -> MeanSign {
        let mut alpha = vec![0f32; w.rows];
        let mut signs = vec![0u8; w.rows * w.cols];
        for r in 0..w.rows {
            let row = w.row(r);
            alpha[r] = row.iter().map(|v| v.abs()).sum::<f32>() / row.len() as f32;
            for (c, &v) in row.iter().enumerate() {
                signs[r * w.cols + c] = (v >= 0.0) as u8;
            }
        }
        MeanSign { rows: w.rows, cols: w.cols, alpha, signs }
    }
}

impl WeightBackend for MeanSign {
    fn tag(&self) -> &'static str {
        "mean-sign"
    }

    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn reconstruct(&self) -> Matrix {
        Matrix::from_fn(self.rows, self.cols, |r, c| {
            let s = if self.signs[r * self.cols + c] == 1 { 1.0 } else { -1.0 };
            self.alpha[r] * s
        })
    }

    fn storage_bits(&self) -> usize {
        self.rows * self.cols + self.alpha.len() * 16
    }

    fn payload_bits_per_weight(&self) -> f64 {
        1.0
    }

    fn write_payload(&self, w: &mut dyn Write) -> Result<()> {
        wire::w_u32(w, self.rows as u32)?;
        wire::w_u32(w, self.cols as u32)?;
        wire::w_f32s(w, &self.alpha)?;
        w.write_all(&self.signs)?;
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn WeightBackend> {
        Box::new(self.clone())
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

fn read_mean_sign(r: &mut dyn Read, _ctx: &BackendIoCtx) -> Result<Box<dyn WeightBackend>> {
    let rows = wire::r_u32(r)? as usize;
    let cols = wire::r_u32(r)? as usize;
    wire::check_dims("mean-sign backend", rows, cols)?;
    let alpha = wire::r_f32s(r, rows)?;
    let mut signs = vec![0u8; rows * cols];
    r.read_exact(&mut signs)?;
    Ok(Box::new(MeanSign { rows, cols, alpha, signs }))
}

// ---- 2. the method strategy -----------------------------------------

#[derive(Debug, Default)]
struct MeanSignQuantizer;

impl Quantizer for MeanSignQuantizer {
    fn name(&self) -> String {
        "Mean-Sign".to_string()
    }

    fn quantize_group(
        &mut self,
        _site: &SiteId,
        weff: &Matrix,
        _act_sq: &[f32],
    ) -> Result<QuantOutcome> {
        Ok(QuantOutcome::Ready(Box::new(MeanSign::quantize(weff))))
    }
}

fn preset(bits: f64) -> QuantConfig {
    QuantConfig { method: "mean-sign".into(), target_bits: bits, ..QuantConfig::default() }
}

fn make(_cfg: &QuantConfig) -> Box<dyn Quantizer> {
    Box::<MeanSignQuantizer>::default()
}

fn main() -> Result<()> {
    // The two registration lines — everything else is method-local code.
    registry::register(MethodEntry {
        key: "mean-sign",
        display: "Mean-Sign",
        aliases: &[],
        takes_bits: true,
        default_bits: 1.0,
        preset,
        make,
    });
    register_backend("mean-sign", read_mean_sign);

    // A hermetic tiny model (no artifacts needed).
    let (raw, corpus_bytes) = tiny_raw_model(17);

    // Quantize by registry name — the pipeline has never heard of us.
    let cfg = registry::get("mean-sign-1.0")?;
    let qm = btc_llm::quant::quantize_model(&raw, &corpus_bytes, &cfg)?;
    println!(
        "quantized with {} @ {:.2} bits: payload {:.2} bits/weight, rel err {:.4}",
        qm.stats.method, qm.stats.target_bits, qm.stats.payload_bits, qm.stats.mean_rel_error
    );
    assert_eq!(qm.model.blocks[0].wq.backend_name(), "mean-sign");

    // Serialize through QLM1 and reload — the container round-trips
    // the custom tag via the backend registry.
    let dir = std::env::temp_dir().join("btc_custom_method");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("mean_sign.qlm");
    qweights::save(&path, &qm.model)?;
    let mut reloaded = Transformer::from_raw(&raw)?;
    qweights::load_into(&path, &mut reloaded)?;
    println!("QLM1 round-trip OK ({} bytes)", std::fs::metadata(&path)?.len());

    let toks: Vec<u16> = corpus_bytes.iter().take(12).map(|&b| b as u16).collect();
    let a = qm.model.forward(&toks);
    reloaded.cache_dense_all();
    let b = reloaded.forward(&toks);
    assert_eq!(a.data, b.data, "reloaded logits must be bit-identical");
    println!("reloaded forward logits bit-identical");

    // Serve the reloaded model — the coordinator is method-agnostic.
    reloaded.prepare_engines();
    let server = Server::start(reloaded, 2, Duration::from_millis(2), 7);
    let tok = ByteTokenizer::default();
    let rx = server.submit(tok.encode("the cat "), 8, 0.0)?;
    let resp = rx.recv().expect("response");
    println!(
        "served completion: {:?} ({} new tokens)",
        tok.decode(&resp.tokens[resp.prompt_len..]),
        resp.tokens.len() - resp.prompt_len
    );
    server.shutdown();
    println!("custom method end-to-end OK");
    Ok(())
}
