//! Quickstart: the whole BTC pipeline on one weight matrix.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! 1. make an "LLM-like" weight matrix (heavy-tailed, outlier columns)
//! 2. fit the learnable transformation T = D± (P1 ⊗ P2)
//! 3. ARB-binarize the transformed weight (grouped scales)
//! 4. compress the sign matrix with the binary codebook
//! 5. run the LUT-GEMM engine and check it against the dense product

use std::sync::Arc;

use btc_llm::engine::{EngineCtx, LutGemmEngine};
use btc_llm::quant::arb::arb_quantize;
use btc_llm::quant::codebook::{collect_vectors, BinaryCodebook, CodebookLayer};
use btc_llm::quant::transform::{fit, FitConfig};
use btc_llm::tensor::stats::rel_error;
use btc_llm::tensor::Matrix;
use btc_llm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let (out, inp, v, c) = (192, 128, 16, 512);

    // 1. "LLM-like" weights + calibration activations with hot channels.
    let hot: Vec<f32> = (0..inp).map(|ch| if ch % 16 == 0 { 6.0 } else { 1.0 }).collect();
    let w = Matrix::from_fn(out, inp, |_, ch| rng.heavy_tailed(0.03, 6.0) * 0.05 * hot[ch].sqrt());
    let x = Matrix::from_fn(128, inp, |_, ch| rng.normal() * hot[ch]);
    println!("weights: {out}x{inp}, activation max|x| = {:.2}", x.max_abs());

    // 2. learnable transformation.
    let (t, stats) = fit(&x, &[&w], &FitConfig::default());
    println!(
        "transform fit: block loss {:.1} -> {:.1} ({} sigma flips)",
        stats.initial_loss, stats.final_loss, stats.sigma_flips
    );
    let xt = t.apply(&x);
    println!("transformed activation max|x| = {:.2}", xt.max_abs());

    // 3. grouped ARB binarization of the transformed weight.
    let wt = t.transform_weight(&w);
    let groups = vec![0u16; inp];
    let bl = arb_quantize(&wt, &groups, 1, 15);
    println!("ARB binarized: rel err {:.4}, {:.2} bits/weight stored",
             rel_error(&wt.data, &bl.reconstruct().data), bl.bits_per_weight());

    // 4. binary codebook (sub-1-bit).
    let vectors = collect_vectors(&bl, v);
    let (cb, assign, cstats) = BinaryCodebook::build(&vectors, v, c, 5);
    let cl = CodebookLayer::from_assignments(&bl, Arc::new(cb), assign);
    println!(
        "codebook: {} vectors -> c={} ({} unique, exact={}), {:.3} index bits/weight",
        cstats.n_vectors,
        cstats.c,
        cstats.n_unique,
        cstats.exact,
        cl.codebook.index_bits() as f64 / v as f64
    );
    println!("codebook rel err {:.4}", rel_error(&wt.data, &cl.reconstruct().data));

    // 5. LUT-GEMM engine == dense reconstruction.
    let eng = LutGemmEngine::try_with_ctx(&cl, &EngineCtx::current()).expect("block-aligned");
    let y_fast = eng.forward(&xt);
    let y_ref = xt.matmul_bt(&cl.reconstruct());
    let gemm_err = rel_error(&y_ref.data, &y_fast.data);
    println!("LUT-GEMM vs dense reconstruction: rel err {gemm_err:.2e}");
    assert!(gemm_err < 1e-5);

    // End-to-end: quantized product vs the original fp product.
    let y_fp = x.matmul_bt(&w);
    println!(
        "end-to-end output rel err (fp vs BTC sub-1-bit): {:.4}",
        rel_error(&y_fp.data, &y_fast.data)
    );
    println!("quickstart OK");
    Ok(())
}
