//! Serving driver: start the coordinator with a BTC-quantized model
//! (LUT-GEMM engines on the hot path), replay a batched request trace
//! from the tinywiki prompt generator, and report latency/throughput.
//!
//! ```bash
//! cargo run --release --example serve -- --model tinylm_s --bits 0.8 --requests 24 --threads 4
//! ```

use std::time::Duration;

use btc_llm::benchsuite::load_workload;
use btc_llm::coordinator::Server;
use btc_llm::data::{corpus, ByteTokenizer};
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let model = args.get_or("model", "tinylm_s").to_string();
    let bits = args.get_f64("bits", 0.8);
    let n_requests = args.get_usize("requests", 24);
    let max_new = args.get_usize("max-new-tokens", 32);
    let max_batch = args.get_usize("max-batch", 8);
    let threads = args.get_usize("threads", 0); // 0 = auto

    let w = load_workload(&model)?;
    println!("quantizing {model} at {bits} bits for serving…");
    let qm = quantize_model(&w.raw, &w.corpus, &QuantConfig::btc(bits))?;
    println!(
        "ready: {} ({} linears, payload {:.2} bits/weight)",
        qm.stats.method, qm.stats.n_linears, qm.stats.payload_bits
    );

    // Server::start prepares the sign-GEMM / LUT-GEMM engines itself.
    let server =
        Server::start_with_threads(qm.model, max_batch, Duration::from_millis(2), 7, threads);
    println!("serving with {} kernel thread(s)", server.threads);
    let tok = ByteTokenizer::default();
    let prompts = corpus::prompts(n_requests, 11);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> =
        prompts.iter().map(|p| server.submit(tok.encode(p), max_new, 0.0)).collect();
    let mut total_new = 0usize;
    for (p, rx) in prompts.iter().zip(rxs) {
        let r = rx.recv().expect("response");
        total_new += r.tokens.len() - r.prompt_len;
        println!(
            "{:>28} | {} ({:.1} ms)",
            format!("'{p}'"),
            tok.decode(&r.tokens[r.prompt_len..]).trim_end().replace('\n', "\\n"),
            r.latency.as_secs_f64() * 1e3
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n{}", server.metrics.summary());
    println!(
        "throughput: {:.1} new tokens/s over {} requests ({:.2}s wall)",
        total_new as f64 / wall,
        n_requests,
        wall
    );
    server.shutdown();
    Ok(())
}
