//! Serving driver: start the coordinator with a BTC-quantized model
//! (LUT-GEMM engines on the hot path), replay a batched request trace
//! from the tinywiki prompt generator, and report latency/throughput —
//! or, with `--stream`, watch tokens arrive one by one over the
//! per-request streaming channel.
//!
//! ```bash
//! cargo run --release --example serve -- --model tinylm_s --bits 0.8 --requests 24 --threads 4
//! cargo run --release --example serve -- --stream --requests 4
//! ```

use std::time::Duration;

use btc_llm::benchsuite::load_workload;
use btc_llm::coordinator::{Server, ServerOptions};
use btc_llm::data::{corpus, ByteTokenizer};
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let model = args.get_or("model", "tinylm_s").to_string();
    let bits = args.get_f64("bits", 0.8);
    let n_requests = args.get_usize("requests", 24);
    let max_new = args.get_usize("max-new-tokens", 32);
    let max_batch = args.get_usize("max-batch", 8);
    let threads = args.get_usize("threads", 0); // 0 = auto
    let prefill_chunk = args.get_usize("prefill-chunk", 32);
    let stream_mode = args.flag("stream");

    let w = load_workload(&model)?;
    println!("quantizing {model} at {bits} bits for serving…");
    let qm = quantize_model(&w.raw, &w.corpus, &QuantConfig::btc(bits))?;
    println!(
        "ready: {} ({} linears, payload {:.2} bits/weight)",
        qm.stats.method, qm.stats.n_linears, qm.stats.payload_bits
    );

    // start_with_opts prepares the sign-GEMM / LUT-GEMM engines itself.
    let server = Server::start_with_opts(
        qm.model,
        ServerOptions {
            max_batch,
            batch_wait: Duration::from_millis(2),
            seed: 7,
            threads,
            prefill_chunk,
            ..ServerOptions::default()
        },
    );
    println!("serving with {} kernel thread(s)", server.threads);
    let tok = ByteTokenizer::default();
    let prompts = corpus::prompts(n_requests, 11);

    if stream_mode {
        // Live per-token delivery, one request at a time.
        use std::io::Write;
        for p in &prompts {
            let (tokens, resp_rx) = server.submit_streaming(tok.encode(p), max_new, 0.0)?;
            print!("{:>28} | ", format!("'{p}'"));
            std::io::stdout().flush()?;
            for t in tokens.iter() {
                print!("{}", tok.decode(&[t]).replace('\n', "\\n"));
                std::io::stdout().flush()?;
            }
            let r = resp_rx.recv()?;
            println!(
                "  [{:?}, ttft {:.1} ms, {:.1} ms total]",
                r.finish,
                r.ttft.as_secs_f64() * 1e3,
                r.latency.as_secs_f64() * 1e3
            );
        }
        println!("\n{}", server.metrics.summary());
        server.shutdown();
        return Ok(());
    }

    let t0 = std::time::Instant::now();
    let rxs = prompts
        .iter()
        .map(|p| server.submit(tok.encode(p), max_new, 0.0))
        .collect::<Result<Vec<_>, _>>()?;
    let mut total_new = 0usize;
    for (p, rx) in prompts.iter().zip(rxs) {
        let r = rx.recv().expect("response");
        total_new += r.tokens.len() - r.prompt_len;
        println!(
            "{:>28} | {} (ttft {:.1} ms, {:.1} ms total)",
            format!("'{p}'"),
            tok.decode(&r.tokens[r.prompt_len..]).trim_end().replace('\n', "\\n"),
            r.ttft.as_secs_f64() * 1e3,
            r.latency.as_secs_f64() * 1e3
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n{}", server.metrics.summary());
    println!(
        "throughput: {:.1} new tokens/s over {} requests ({:.2}s wall)",
        total_new as f64 / wall,
        n_requests,
        wall
    );
    server.shutdown();
    Ok(())
}
