//! End-to-end driver (DESIGN.md §5): proves all layers compose on a
//! real small workload.
//!
//! - replays the build-time training loss curve (L2 JAX trainer,
//!   artifacts/train_metrics_*.txt)
//! - quantizes the trained TinyLM at 1.11 / 0.9 / 0.8 / 0.7 bits with
//!   the full BTC pipeline (learnable transformation + ARB + shared
//!   binary codebook)
//! - evaluates held-out perplexity and the 7 zero-shot probes
//! - prints the memory report
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_pipeline [-- --model tinylm_m --quick]
//! ```

use btc_llm::benchsuite::{eval_lane, fmt_ppl, load_workload};
use btc_llm::eval::memory;
use btc_llm::quant::pipeline::{quantize_model, QuantConfig};
use btc_llm::util::argparse::Args;
use btc_llm::util::benchkit::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let model = args.get_or("model", "tinylm_m").to_string();
    let quick = args.flag("quick");
    let w = load_workload(&model)?;

    // ---- 1. training loss curve (from the L2 build) -------------------
    let metrics_path = btc_llm::artifacts_dir().join(format!("train_metrics_{model}.txt"));
    let metrics = std::fs::read_to_string(&metrics_path)?;
    println!("== training loss curve ({model}, L2 JAX trainer) ==");
    let points: Vec<(usize, f64)> = metrics
        .lines()
        .filter(|l| !l.starts_with('#'))
        .filter_map(|l| {
            let mut it = l.split_whitespace();
            Some((it.next()?.parse().ok()?, it.next()?.parse().ok()?))
        })
        .collect();
    let maxloss = points.iter().map(|p| p.1).fold(0.0f64, f64::max);
    for (step, loss) in points.iter().step_by((points.len() / 12).max(1)) {
        let bar = "#".repeat((loss / maxloss * 50.0) as usize);
        println!("step {step:>4} loss {loss:.4} |{bar}");
    }
    println!("({} params)", w.raw.config.param_count());

    // ---- 2. quantize + evaluate at every bit-width ---------------------
    let eval_tokens = if quick { 1200 } else { 4000 };
    let zs = if quick { Some(16) } else { Some(48) };
    let mut t = Table::new(&["Config", "payload bits", "PPL", "mean acc", "quant(s)"]);
    let fp = eval_lane(&w, &QuantConfig::fp16(), eval_tokens, zs)?;
    t.row(&["FP16".into(), "16.00".into(), fmt_ppl(fp.ppl),
            format!("{:.1}%", fp.mean_acc.unwrap_or(0.0)), format!("{:.1}", fp.quant_secs)]);
    for bits in [1.11, 0.9, 0.8, 0.7] {
        let r = eval_lane(&w, &QuantConfig::btc(bits), eval_tokens, zs)?;
        t.row(&[
            format!("BTC-LLM @ {bits}"),
            format!("{:.2}", r.payload_bits),
            fmt_ppl(r.ppl),
            format!("{:.1}%", r.mean_acc.unwrap_or(0.0)),
            format!("{:.1}", r.quant_secs),
        ]);
    }
    println!("\n== quantization grid ({model}) ==");
    t.print();

    // ---- 3. memory report ----------------------------------------------
    let qm = quantize_model(&w.raw, &w.corpus, &QuantConfig::btc(0.8))?;
    let r = memory::report(&qm.model);
    println!("\n== memory (BTC 0.8) ==");
    println!("fp16 model:    {}", memory::human_bytes(r.fp16_total_bytes));
    println!("quantized:     {} ({:.1}x compression)", memory::human_bytes(r.total_bytes), r.compression);
    println!("  linears:     {}", memory::human_bytes(r.linear_bytes));
    println!("  codebook:    {} ({:.1}% overhead)", memory::human_bytes(r.codebook_bytes), 100.0 * r.codebook_overhead);
    println!("  transforms:  {}", memory::human_bytes(r.transform_bytes));
    println!("  emb/norms:   {}", memory::human_bytes(r.residual_fp16_bytes));
    println!("\ne2e pipeline OK");
    Ok(())
}
